//! `FaultBackend`: deterministic fault injection at the backend seam,
//! and the typed error taxonomy the whole runtime recovers against.
//!
//! This module is the **normative fault model** for the crate. Every
//! layer above the [`Backend`] trait — `DeviceState`,
//! `ReplicatedState`, `Trainer`, the serve plane — classifies failures
//! by downcasting `anyhow` errors to [`RuntimeError`] and reacts per
//! the rules below; anything that does not downcast is a programming
//! or environment error and stays fatal.
//!
//! # Error taxonomy
//!
//! * [`RuntimeError::Transient`] — a single transfer or execution
//!   failed, the device survives. Whether the *operation* is
//!   recoverable in place depends on its ownership mode (see the
//!   `backend` module docs):
//!   - **Borrow-only ops** (host syncs via `gather_to_host` /
//!     `to_literal_sync`, eval/grad-norm executions, serve
//!     executions, `all_reduce_sum`) left every input valid — callers
//!     retry in place.
//!   - **Donating ops** (`train_step`/`apply_step` executions, mask
//!     `scatter_mask_update` installs, `scatter_values_update`) have
//!     already consumed their inputs, exactly as on real hardware
//!     where the donated memory is gone either way. The resident
//!     chain is forfeit; recovery rebuilds it (below).
//! * [`RuntimeError::DeviceLost`] — the device is permanently gone.
//!   Every subsequent operation touching it fails the same way.
//!   Callers quarantine the device: the trainer rebuilds on a healthy
//!   one, `ReplicatedState` drops the replica and re-shards to
//!   survivors, the serve plane stops placing work on it.
//!
//! # The recovery protocol and its parity guarantee
//!
//! Host state is the authority and is never poisoned by a device
//! fault: the `Trainer` keeps a **base snapshot** (params + masks +
//! optimizer state, rebased at every completed host sync or
//! checkpoint restore) and a **journal** of every step executed since
//! — batch, step scalars, and any mask/value installs a refresh made.
//! Recovery re-uploads the base, replays the journal in order, and
//! resumes. Because every execution is deterministic and the journal
//! replays the *results* of host-side mask selection (never re-running
//! Top-K, so the host RNG and store are not double-mutated), the
//! recovered resident state is **bitwise identical** to the
//! fault-free run — the chaos parity suite
//! (`rust/tests/chaos_recovery.rs`) pins final θ/masks/opt to the
//! fault-free bits under both `sim` and `strict` inner backends.
//! Recovery adds no traffic to the fault-free path: the base is
//! cloned host-side at syncs that already happen, and the journal
//! records host copies of data already being uploaded.
//!
//! # Injection
//!
//! [`FaultBackend`] wraps any [`Backend`] (same wrapper position as
//! `StrictBackend`) and injects faults from a seeded [`FaultPlan`]:
//! each fault-eligible operation (metered transfers, executions,
//! all-reduces, consuming scatter updates) advances a deterministic
//! PCG64 stream and fails with `Transient` at the plan's per-kind
//! probability, up to a `max` cap that guarantees faulted runs
//! terminate; `lose=<device>@<op>` kills a device permanently once
//! the global op counter reaches `<op>`. Select it with
//! `TOPKAST_BACKEND=faulty` (host-sim inner) or `faulty-strict`
//! (donation-enforcing inner) and describe the plan in
//! `TOPKAST_FAULTS`, e.g.
//! `TOPKAST_FAULTS="seed=3;transfer=0.02;exec=0.05;max=16;lose=1@40"`.
//! Metering, numerics and device layout delegate untouched, so a
//! faulted run that recovers is bit-comparable to a clean one.

use std::collections::BTreeSet;
use std::fmt;
use std::sync::{Arc, Mutex};

use anyhow::{bail, Context, Result};

use crate::tensor::SparseSet;
use crate::util::rng::Pcg64;
use crate::xla;

use super::backend::{Backend, BufferOps, ExecInput};

/// The environment variable holding the textual [`FaultPlan`] for
/// `TOPKAST_BACKEND=faulty` runs (and for suites that read it to pick
/// chaos seeds).
pub const FAULTS_ENV: &str = "TOPKAST_FAULTS";

/// Typed runtime failure, carried through `anyhow` chains and
/// recovered by downcast (`err.downcast_ref::<RuntimeError>()` — the
/// helpers below wrap this). See the module docs for the taxonomy.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum RuntimeError {
    /// One transfer or execution failed; the device survives. Donated
    /// inputs of the failed call are gone regardless.
    Transient {
        device: usize,
        op: &'static str,
    },
    /// The device is permanently gone; everything touching it fails.
    DeviceLost { device: usize },
}

impl fmt::Display for RuntimeError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            RuntimeError::Transient { device, op } => {
                write!(f, "transient fault: {op} failed on device {device}")
            }
            RuntimeError::DeviceLost { device } => {
                write!(f, "device {device} lost (permanent)")
            }
        }
    }
}

impl std::error::Error for RuntimeError {}

impl RuntimeError {
    /// The typed failure behind an `anyhow` error, if any — works
    /// through `.context(...)` chains.
    pub fn classify(err: &anyhow::Error) -> Option<&RuntimeError> {
        err.downcast_ref::<RuntimeError>()
    }

    /// True when the error is a transient device fault (retryable at
    /// some level; see module docs for which level).
    pub fn is_transient(err: &anyhow::Error) -> bool {
        matches!(Self::classify(err), Some(RuntimeError::Transient { .. }))
    }

    /// The device a permanent-loss error names, if it is one.
    pub fn lost_device(err: &anyhow::Error) -> Option<usize> {
        match Self::classify(err) {
            Some(RuntimeError::DeviceLost { device }) => Some(*device),
            _ => None,
        }
    }

    /// True when the error carries either runtime-fault variant —
    /// i.e. recovery machinery should engage rather than propagate.
    pub fn is_fault(err: &anyhow::Error) -> bool {
        Self::classify(err).is_some()
    }
}

/// A deterministic fault schedule. Parsed from `TOPKAST_FAULTS` (or a
/// `RunSpec`'s `faults` string) as `;`- or `,`-separated `key=value`
/// pairs: `seed` (PCG64 stream seed), `transfer` / `exec`
/// (per-operation fault probabilities in [0,1]), `max` (cap on total
/// transient faults injected — guarantees termination), and
/// `lose=<device>@<op>` (permanent device loss once the op counter
/// reaches `<op>`).
#[derive(Debug, Clone, PartialEq)]
pub struct FaultPlan {
    pub seed: u64,
    pub transfer: f64,
    pub exec: f64,
    pub max: usize,
    pub lose: Option<(usize, u64)>,
}

impl Default for FaultPlan {
    fn default() -> Self {
        FaultPlan {
            seed: 0,
            transfer: 0.0,
            exec: 0.0,
            max: 8,
            lose: None,
        }
    }
}

impl FaultPlan {
    /// Parse the textual plan format (see type docs). The empty
    /// string is the default (fault-free) plan.
    pub fn parse(text: &str) -> Result<FaultPlan> {
        let mut plan = FaultPlan::default();
        for pair in text.split([';', ',']).map(str::trim).filter(|p| !p.is_empty()) {
            let (key, value) = pair
                .split_once('=')
                .with_context(|| format!("fault plan entry {pair:?} is not key=value"))?;
            match key.trim() {
                "seed" => {
                    plan.seed = value
                        .trim()
                        .parse()
                        .with_context(|| format!("fault plan seed {value:?}"))?
                }
                "transfer" => {
                    plan.transfer = parse_probability(value, "transfer")?;
                }
                "exec" => {
                    plan.exec = parse_probability(value, "exec")?;
                }
                "max" => {
                    plan.max = value
                        .trim()
                        .parse()
                        .with_context(|| format!("fault plan max {value:?}"))?
                }
                "lose" => {
                    let (device, at) = value
                        .trim()
                        .split_once('@')
                        .with_context(|| {
                            format!("fault plan lose {value:?} is not <device>@<op>")
                        })?;
                    plan.lose = Some((
                        device
                            .parse()
                            .with_context(|| format!("fault plan lose device {device:?}"))?,
                        at.parse()
                            .with_context(|| format!("fault plan lose op count {at:?}"))?,
                    ));
                }
                other => bail!(
                    "unknown fault plan key {other:?} (expected seed, transfer, \
                     exec, max or lose)"
                ),
            }
        }
        Ok(plan)
    }

    /// The plan `TOPKAST_FAULTS` describes (default plan when unset).
    pub fn from_env() -> Result<FaultPlan> {
        match std::env::var(FAULTS_ENV) {
            Err(std::env::VarError::NotPresent) => Ok(FaultPlan::default()),
            Err(e) => bail!("reading {FAULTS_ENV}: {e}"),
            Ok(text) => {
                FaultPlan::parse(&text).with_context(|| format!("parsing {FAULTS_ENV}"))
            }
        }
    }
}

fn parse_probability(value: &str, key: &str) -> Result<f64> {
    let p: f64 = value
        .trim()
        .parse()
        .with_context(|| format!("fault plan {key} {value:?}"))?;
    if !(0.0..=1.0).contains(&p) {
        bail!("fault plan {key}={p} outside [0, 1]");
    }
    Ok(p)
}

/// Which probability knob an injection point draws against.
#[derive(Clone, Copy)]
enum OpKind {
    Transfer,
    Exec,
}

/// Shared mutable schedule state: one deterministic stream per
/// backend instance, advanced by every fault-eligible op in program
/// order (single-threaded runtime, so program order is total).
struct FaultState {
    plan: FaultPlan,
    rng: Pcg64,
    ops: u64,
    fired: usize,
    lost: BTreeSet<usize>,
}

impl FaultState {
    fn new(plan: FaultPlan) -> FaultState {
        let rng = Pcg64::new(plan.seed ^ 0xFA17, 0xFA17);
        FaultState {
            plan,
            rng,
            ops: 0,
            fired: 0,
            lost: BTreeSet::new(),
        }
    }

    /// Advance the schedule for one fault-eligible op on `device`;
    /// `Err` means the fault fires (typed [`RuntimeError`]).
    fn check(&mut self, device: usize, kind: OpKind, op: &'static str) -> Result<()> {
        self.ops += 1;
        if let Some((dev, at)) = self.plan.lose {
            if self.ops >= at {
                self.lost.insert(dev);
            }
        }
        if self.lost.contains(&device) {
            return Err(RuntimeError::DeviceLost { device }.into());
        }
        let p = match kind {
            OpKind::Transfer => self.plan.transfer,
            OpKind::Exec => self.plan.exec,
        };
        if p > 0.0 {
            // always draw, so the schedule depends only on (seed, op
            // sequence), not on how many faults already fired
            let draw = self.rng.next_f64();
            if draw < p && self.fired < self.plan.max {
                self.fired += 1;
                return Err(RuntimeError::Transient { device, op }.into());
            }
        }
        Ok(())
    }
}

/// Any backend plus a deterministic fault schedule. See module docs.
#[derive(Clone)]
pub struct FaultBackend<B: Backend> {
    inner: B,
    state: Arc<Mutex<FaultState>>,
}

/// An inner-backend buffer plus a handle on the shared schedule (its
/// data accesses are injection points too).
#[derive(Clone)]
pub struct FaultBuffer<B: Backend> {
    inner: B::Buffer,
    state: Arc<Mutex<FaultState>>,
}

pub struct FaultExecutable<B: Backend> {
    inner: B::Executable,
}

impl<B: Backend> FaultBackend<B> {
    pub fn new(inner: B, plan: FaultPlan) -> FaultBackend<B> {
        FaultBackend {
            inner,
            state: Arc::new(Mutex::new(FaultState::new(plan))),
        }
    }

    /// Wrap with the plan `TOPKAST_FAULTS` describes.
    pub fn from_env(inner: B) -> Result<FaultBackend<B>> {
        Ok(FaultBackend::new(inner, FaultPlan::from_env()?))
    }

    /// Transient faults injected so far.
    pub fn faults_fired(&self) -> usize {
        self.state.lock().expect("fault state poisoned").fired
    }

    /// Devices the schedule has permanently killed so far.
    pub fn lost_devices(&self) -> Vec<usize> {
        self.state
            .lock()
            .expect("fault state poisoned")
            .lost
            .iter()
            .copied()
            .collect()
    }

    /// Return a lost device to service — the chaos stand-in for
    /// swapping in a replacement part, feeding the trainer's elastic
    /// `join_replica` path. Clears the armed `lose` threshold when it
    /// targets this device (otherwise the schedule would re-kill the
    /// newcomer on its next op); transient probabilities keep drawing
    /// exactly as before.
    pub fn revive_device(&self, device: usize) {
        let mut state = self.state.lock().expect("fault state poisoned");
        state.lost.remove(&device);
        if state.plan.lose.is_some_and(|(dev, _)| dev == device) {
            state.plan.lose = None;
        }
    }

    fn check(&self, device: usize, kind: OpKind, op: &'static str) -> Result<()> {
        self.state
            .lock()
            .expect("fault state poisoned")
            .check(device, kind, op)
    }
}

impl<B: Backend> FaultBuffer<B> {
    fn check(&self, kind: OpKind, op: &'static str) -> Result<()> {
        let device = self.inner.device();
        self.state
            .lock()
            .expect("fault state poisoned")
            .check(device, kind, op)
    }

    fn wrap(&self, inner: B::Buffer) -> FaultBuffer<B> {
        FaultBuffer {
            inner,
            state: Arc::clone(&self.state),
        }
    }
}

impl<B: Backend> BufferOps for FaultBuffer<B> {
    fn element_count(&self) -> usize {
        self.inner.element_count()
    }

    fn element_type(&self) -> Option<xla::ElemType> {
        self.inner.element_type()
    }

    fn is_tuple(&self) -> bool {
        self.inner.is_tuple()
    }

    fn device(&self) -> usize {
        self.inner.device()
    }

    fn to_literal_sync(&self) -> Result<xla::Literal> {
        self.check(OpKind::Transfer, "to_literal_sync")?;
        self.inner.to_literal_sync()
    }

    fn gather_to_host(&self, indices: &[u32]) -> Result<Vec<f32>> {
        self.check(OpKind::Transfer, "gather_to_host")?;
        self.inner.gather_to_host(indices)
    }

    fn tuple_parts(self) -> Result<Vec<Self>> {
        // no bus traffic (parts alias the tuple) — not an injection
        // point; a fault here would be indistinguishable from an
        // execute fault anyway, since callers always split immediately
        let state = Arc::clone(&self.state);
        Ok(self
            .inner
            .tuple_parts()?
            .into_iter()
            .map(|inner| FaultBuffer {
                inner,
                state: Arc::clone(&state),
            })
            .collect())
    }

    fn scatter_mask_update(self, added: &[u32], removed: &[u32]) -> Result<Self> {
        // injected *before* the inner call: the old mask buffer is
        // consumed either way (donation), which is exactly the
        // non-idempotent install failure recovery must handle
        self.check(OpKind::Transfer, "scatter_mask_update")?;
        let state = Arc::clone(&self.state);
        Ok(FaultBuffer {
            inner: self.inner.scatter_mask_update(added, removed)?,
            state,
        })
    }

    fn scatter_values_update(self, indices: &[u32], values: &[f32]) -> Result<Self> {
        self.check(OpKind::Transfer, "scatter_values_update")?;
        let state = Arc::clone(&self.state);
        Ok(FaultBuffer {
            inner: self.inner.scatter_values_update(indices, values)?,
            state,
        })
    }

    fn debug_read_f32(&self) -> Option<Vec<f32>> {
        // unmetered diagnostic peek — never faulted, never counted
        self.inner.debug_read_f32()
    }
}

impl<B: Backend> Backend for FaultBackend<B> {
    type Client = FaultBackend<B>;
    type Buffer = FaultBuffer<B>;
    type Executable = FaultExecutable<B>;

    fn name(&self) -> &'static str {
        "faulty"
    }

    fn platform_name(&self) -> String {
        self.inner.platform_name()
    }

    fn device_count(&self) -> usize {
        self.inner.device_count()
    }

    fn client(&self) -> Self::Client {
        self.clone()
    }

    fn buffer_from_host_buffer<T: xla::NativeType>(
        &self,
        data: &[T],
        dims: &[usize],
        device: Option<usize>,
    ) -> Result<Self::Buffer> {
        self.check(device.unwrap_or(0), OpKind::Transfer, "buffer_from_host_buffer")?;
        Ok(FaultBuffer {
            inner: self.inner.buffer_from_host_buffer(data, dims, device)?,
            state: Arc::clone(&self.state),
        })
    }

    fn mask_from_indices(
        &self,
        dims: &[usize],
        indices: &[u32],
        device: Option<usize>,
    ) -> Result<Self::Buffer> {
        self.check(device.unwrap_or(0), OpKind::Transfer, "mask_from_indices")?;
        Ok(FaultBuffer {
            inner: self.inner.mask_from_indices(dims, indices, device)?,
            state: Arc::clone(&self.state),
        })
    }

    fn compile(&self, comp: &xla::XlaComputation) -> Result<Self::Executable> {
        // host-side compilation — not an injection point
        Ok(FaultExecutable {
            inner: self.inner.compile(comp)?,
        })
    }

    fn execute(
        &self,
        exe: &Self::Executable,
        inputs: Vec<ExecInput<'_, Self>>,
    ) -> Result<Vec<Self::Buffer>> {
        let device = inputs
            .first()
            .map(|i| i.buffer().device())
            .unwrap_or(0);
        // injected before dispatch; dropping `inputs` on the error
        // path frees the donated buffers — consumed per the ownership
        // contract, exactly like a failed execution on real hardware
        self.check(device, OpKind::Exec, "execute")?;
        let mut unwrapped: Vec<ExecInput<'_, B>> = Vec::with_capacity(inputs.len());
        for input in &inputs {
            unwrapped.push(match input {
                // donate a clone-alias: a strict inner shares the
                // donation flag across clones, so the real ownership
                // mode is still seen and enforced; a sim inner just
                // drops the alias
                ExecInput::Donate(b) => ExecInput::Donate(b.inner.clone()),
                ExecInput::Borrow(b) => ExecInput::Borrow(&b.inner),
            });
        }
        let outs = self.inner.execute(exe.inner_ref(), unwrapped)?;
        drop(inputs);
        Ok(outs
            .into_iter()
            .map(|inner| FaultBuffer {
                inner,
                state: Arc::clone(&self.state),
            })
            .collect())
    }

    fn all_reduce_sum(&self, inputs: &[&Self::Buffer]) -> Result<Vec<Self::Buffer>> {
        let device = inputs.first().map(|b| b.inner.device()).unwrap_or(0);
        self.check(device, OpKind::Exec, "all_reduce_sum")?;
        let refs: Vec<&B::Buffer> = inputs.iter().map(|b| &b.inner).collect();
        Ok(self
            .inner
            .all_reduce_sum(&refs)?
            .into_iter()
            .map(|inner| FaultBuffer {
                inner,
                state: Arc::clone(&self.state),
            })
            .collect())
    }

    fn all_reduce_sum_sparse(
        &self,
        inputs: &[&Self::Buffer],
        set: &SparseSet,
    ) -> Result<Vec<Self::Buffer>> {
        let device = inputs.first().map(|b| b.inner.device()).unwrap_or(0);
        self.check(device, OpKind::Exec, "all_reduce_sum_sparse")?;
        let refs: Vec<&B::Buffer> = inputs.iter().map(|b| &b.inner).collect();
        Ok(self
            .inner
            .all_reduce_sum_sparse(&refs, set)?
            .into_iter()
            .map(|inner| FaultBuffer {
                inner,
                state: Arc::clone(&self.state),
            })
            .collect())
    }

    fn transfer_stats(&self) -> xla::TransferSnapshot {
        self.inner.transfer_stats()
    }

    fn device_transfer_stats(&self, device: usize) -> Result<xla::TransferSnapshot> {
        self.inner.device_transfer_stats(device)
    }
}

impl<B: Backend> FaultExecutable<B> {
    fn inner_ref(&self) -> &B::Executable {
        &self.inner
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::xla::PjRtClient;

    fn sim(devices: usize) -> PjRtClient {
        PjRtClient::cpu_with_devices(devices).unwrap()
    }

    #[test]
    fn plan_parses_every_key_and_rejects_junk() {
        let plan =
            FaultPlan::parse("seed=3; transfer=0.25, exec=0.5;max=4;lose=1@40").unwrap();
        assert_eq!(
            plan,
            FaultPlan {
                seed: 3,
                transfer: 0.25,
                exec: 0.5,
                max: 4,
                lose: Some((1, 40)),
            }
        );
        assert_eq!(FaultPlan::parse("").unwrap(), FaultPlan::default());
        assert!(FaultPlan::parse("transfer=2.0").is_err());
        assert!(FaultPlan::parse("warp=0.1").is_err());
        assert!(FaultPlan::parse("lose=1").is_err());
        assert!(FaultPlan::parse("seed").is_err());
    }

    #[test]
    fn schedule_is_deterministic_and_capped() {
        let plan = FaultPlan::parse("seed=7;transfer=0.5;max=3").unwrap();
        let fire = |plan: FaultPlan| -> Vec<bool> {
            let backend = FaultBackend::new(sim(1), plan);
            (0..32)
                .map(|_| {
                    backend
                        .buffer_from_host_buffer::<f32>(&[1.0], &[1], None)
                        .is_err()
                })
                .collect()
        };
        let a = fire(plan.clone());
        let b = fire(plan);
        assert_eq!(a, b, "same plan must fire the same schedule");
        assert_eq!(a.iter().filter(|f| **f).count(), 3, "max caps fired faults");
        assert!(a.iter().any(|f| *f), "p=0.5 over 32 ops must fire");
    }

    #[test]
    fn faults_are_typed_and_classifiable() {
        let plan = FaultPlan::parse("transfer=1.0;max=1").unwrap();
        let backend = FaultBackend::new(sim(1), plan);
        let err = backend
            .buffer_from_host_buffer::<f32>(&[1.0], &[1], None)
            .unwrap_err();
        assert!(RuntimeError::is_transient(&err), "{err}");
        assert!(RuntimeError::is_fault(&err));
        assert_eq!(RuntimeError::lost_device(&err), None);
        // classification survives a context chain
        let wrapped = err.context("uploading params");
        assert!(RuntimeError::is_transient(&wrapped), "{wrapped}");
        // cap reached: next op goes through
        assert!(backend
            .buffer_from_host_buffer::<f32>(&[1.0], &[1], None)
            .is_ok());
        assert_eq!(backend.faults_fired(), 1);
    }

    #[test]
    fn lost_devices_stay_lost_and_survivors_work() {
        let plan = FaultPlan::parse("lose=1@3").unwrap();
        let backend = FaultBackend::new(sim(2), plan);
        // ops 1 and 2: device 1 still alive
        assert!(backend
            .buffer_from_host_buffer::<f32>(&[1.0], &[1], Some(1))
            .is_ok());
        assert!(backend
            .buffer_from_host_buffer::<f32>(&[1.0], &[1], Some(1))
            .is_ok());
        // op 3 onward: device 1 is gone, permanently
        for _ in 0..3 {
            let err = backend
                .buffer_from_host_buffer::<f32>(&[1.0], &[1], Some(1))
                .unwrap_err();
            assert_eq!(RuntimeError::lost_device(&err), Some(1), "{err}");
        }
        // device 0 is untouched
        assert!(backend
            .buffer_from_host_buffer::<f32>(&[1.0], &[1], Some(0))
            .is_ok());
        assert_eq!(backend.lost_devices(), vec![1]);
    }

    #[test]
    fn revived_device_rejoins_and_is_not_rekilled() {
        let plan = FaultPlan::parse("lose=1@1").unwrap();
        let backend = FaultBackend::new(sim(2), plan);
        let err = backend
            .buffer_from_host_buffer::<f32>(&[1.0], &[1], Some(1))
            .unwrap_err();
        assert_eq!(RuntimeError::lost_device(&err), Some(1), "{err}");
        // the replacement part arrives: the device serves again, and
        // the spent lose threshold must not re-kill it on the next op
        backend.revive_device(1);
        assert!(backend.lost_devices().is_empty());
        for _ in 0..3 {
            assert!(backend
                .buffer_from_host_buffer::<f32>(&[1.0], &[1], Some(1))
                .is_ok());
        }
    }

    #[test]
    fn fault_free_plan_delegates_metering_exactly() {
        let faulty = FaultBackend::new(sim(1), FaultPlan::default());
        let raw = sim(1);
        faulty
            .buffer_from_host_buffer::<f32>(&[1.0, 2.0, 3.0], &[3], None)
            .unwrap();
        raw.buffer_from_host_buffer::<f32>(&[1.0, 2.0, 3.0], &[3], None)
            .unwrap();
        assert_eq!(faulty.transfer_stats(), raw.transfer_stats());
    }
}
