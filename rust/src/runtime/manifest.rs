//! artifacts/manifest.json — the contract between the python compile
//! path and this runtime. Mirrors python/compile/specs.py + aot.py.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

use crate::tensor::Shape;
use crate::util::json::Json;

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum InitKind {
    Normal,
    Uniform,
    Zeros,
    Ones,
}

impl InitKind {
    fn parse(s: &str) -> Result<Self> {
        Ok(match s {
            "normal" => InitKind::Normal,
            "uniform" => InitKind::Uniform,
            "zeros" => InitKind::Zeros,
            "ones" => InitKind::Ones,
            _ => bail!("unknown init kind {s:?}"),
        })
    }
}

/// One parameter tensor (python ParamSpec).
#[derive(Clone, Debug)]
pub struct ParamSpec {
    pub name: String,
    pub shape: Shape,
    pub init: InitKind,
    pub init_scale: f32,
    pub sparse: bool,
    /// multiply-accumulates per example in the forward pass (FLOPs model)
    pub mac: u64,
}

/// One runtime input/output of an artifact (python IoSpec).
#[derive(Clone, Debug)]
pub struct IoSpec {
    pub name: String,
    pub shape: Shape,
    pub dtype: Dtype,
}

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Dtype {
    F32,
    I32,
}

/// One lowered HLO artifact (train / eval / grad_norms).
#[derive(Clone, Debug)]
pub struct ArtifactSpec {
    pub file: PathBuf,
    pub inputs: Vec<IoSpec>,
    pub outputs: Vec<IoSpec>,
}

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Optimizer {
    Sgd,
    Adam,
}

impl Optimizer {
    pub fn slots(&self) -> usize {
        match self {
            Optimizer::Sgd => 1,
            Optimizer::Adam => 2,
        }
    }
}

/// Artifacts for data-parallel replication (see `runtime::replicated`):
/// per-replica partial-gradient artifacts over the batch shards, and a
/// replicated apply artifact that follows the train input convention
/// with the batch positions carrying the all-reduced gradient payload
/// instead of raw examples. Real manifests ship these under the
/// optional `"replication"` key (aot.py `--replicas`, `"grads"` array
/// or legacy single `"grad"`); the synthetic models build theirs in
/// memory for any concrete replica count. With tree-aligned remainder
/// sharding the shards of a non-pow2 split are *unequal*, so each
/// replica gets its own shard-sized artifact entry (`grads[r]`);
/// equal-size shards may share one compiled file.
#[derive(Clone, Debug)]
pub struct ReplicationSpec {
    /// The replica count the shard-sized grad artifacts were built for.
    pub replicas: usize,
    /// One artifact per replica (canonical order): that replica's
    /// batch shard in, the gradient payload out (the outputs are
    /// exactly what the step all-reduces).
    pub grads: Vec<ArtifactSpec>,
    /// Replicated on every device: train-convention inputs with the
    /// batch slots carrying the reduced payload; train outputs.
    pub apply: ArtifactSpec,
}

/// Everything the coordinator needs to drive one model configuration.
#[derive(Clone, Debug)]
pub struct ModelEntry {
    pub name: String,
    pub kind: String, // "mlp" | "lm" | "cnn"
    pub optimizer: Optimizer,
    pub params: Vec<ParamSpec>,
    pub train: ArtifactSpec,
    pub eval: ArtifactSpec,
    pub grad_norms: ArtifactSpec,
    /// Data-parallel artifacts, when the model carries them.
    pub replication: Option<ReplicationSpec>,
    /// Raw config map (batch_size, seq_len, vocab, classes...).
    pub config: BTreeMap<String, Json>,
}

/// Addressable slices of the train artifact's flat input/output
/// vectors. The AOT convention (python/compile/aot.py) is
///
///   inputs:  θ (np) | m_fwd (ns) | m_bwd (ns) | opt (np·slots)
///            | x, y | lr, step, reg_scale, inv_d
///   outputs: θ' (np) | opt' (np·slots) | loss
///
/// Grouping the positions here (instead of re-deriving offsets at
/// every call site) is what lets `runtime::device_state` address
/// "the params", "the masks", "the batch" as slices when deciding
/// what stays resident and what streams per step.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct TrainLayout {
    pub params: std::ops::Range<usize>,
    pub masks_fwd: std::ops::Range<usize>,
    pub masks_bwd: std::ops::Range<usize>,
    pub opt: std::ops::Range<usize>,
    pub batch: std::ops::Range<usize>,
    pub scalars: std::ops::Range<usize>,
    pub out_params: std::ops::Range<usize>,
    pub out_opt: std::ops::Range<usize>,
    pub out_loss: usize,
}

/// Input grouping shared by the eval and grad_norms artifacts:
/// θ (np) | m_fwd (ns) | x, y.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct EvalLayout {
    pub params: std::ops::Range<usize>,
    pub masks_fwd: std::ops::Range<usize>,
    pub batch: std::ops::Range<usize>,
}

/// Buffer-table addressing for N data-parallel replicas: the train
/// layout instantiated once per device, keyed by **(replica, tensor)**
/// instead of tensor alone. The single-device `TrainLayout` silently
/// assumed one buffer table; `ReplicatedState` keeps one table per
/// replica in canonical order, and this wrapper names that addressing
/// (per-replica slot ranges plus the flat↔(replica, slot) mapping for
/// anything that views the replica set as one concatenated table —
/// e.g. the per-replica transfer-count accounting in the parity
/// suite).
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct ReplicatedLayout {
    pub replicas: usize,
    pub per_replica: TrainLayout,
}

impl ReplicatedLayout {
    /// Input slots one replica contributes to the flat table.
    pub fn inputs_per_replica(&self) -> usize {
        self.per_replica.scalars.end
    }

    /// Flat index of input slot `input` on `replica` (canonical order:
    /// replica-major, slot-minor).
    pub fn input_index(&self, replica: usize, input: usize) -> usize {
        debug_assert!(replica < self.replicas && input < self.inputs_per_replica());
        replica * self.inputs_per_replica() + input
    }

    /// Inverse of [`ReplicatedLayout::input_index`].
    pub fn owner(&self, flat: usize) -> (usize, usize) {
        (flat / self.inputs_per_replica(), flat % self.inputs_per_replica())
    }

    /// Total input slots across the replica set.
    pub fn total_inputs(&self) -> usize {
        self.replicas * self.inputs_per_replica()
    }
}

impl ModelEntry {
    /// Input/output grouping of the train artifact, validated against
    /// the artifact's declared arity.
    pub fn train_layout(&self) -> Result<TrainLayout> {
        let np = self.params.len();
        let ns = self.sparse_params().len();
        let slots = self.optimizer.slots();
        let layout = TrainLayout {
            params: 0..np,
            masks_fwd: np..np + ns,
            masks_bwd: np + ns..np + 2 * ns,
            opt: np + 2 * ns..np + 2 * ns + np * slots,
            batch: np + 2 * ns + np * slots..np + 2 * ns + np * slots + 2,
            scalars: np + 2 * ns + np * slots + 2..np + 2 * ns + np * slots + 6,
            out_params: 0..np,
            out_opt: np..np + np * slots,
            out_loss: np + np * slots,
        };
        if self.train.inputs.len() != layout.scalars.end {
            bail!(
                "model {}: train artifact declares {} inputs, layout expects {}",
                self.name,
                self.train.inputs.len(),
                layout.scalars.end
            );
        }
        if self.train.outputs.len() != layout.out_loss + 1 {
            bail!(
                "model {}: train artifact declares {} outputs, layout expects {}",
                self.name,
                self.train.outputs.len(),
                layout.out_loss + 1
            );
        }
        Ok(layout)
    }

    /// The (replica, tensor)-keyed layout for an N-replica run:
    /// validates the train layout once and wraps it with the replica
    /// addressing.
    pub fn replicated_layout(&self, replicas: usize) -> Result<ReplicatedLayout> {
        if replicas == 0 {
            bail!("model {}: replica count must be >= 1", self.name);
        }
        Ok(ReplicatedLayout { replicas, per_replica: self.train_layout()? })
    }

    /// Input grouping of an eval-convention artifact (eval itself and
    /// grad_norms share it).
    pub fn eval_layout(&self, spec: &ArtifactSpec) -> Result<EvalLayout> {
        let np = self.params.len();
        let ns = self.sparse_params().len();
        let layout = EvalLayout {
            params: 0..np,
            masks_fwd: np..np + ns,
            batch: np + ns..np + ns + 2,
        };
        if spec.inputs.len() != layout.batch.end {
            bail!(
                "model {}: artifact {:?} declares {} inputs, layout expects {}",
                self.name,
                spec.file.file_name().unwrap_or_default(),
                spec.inputs.len(),
                layout.batch.end
            );
        }
        Ok(layout)
    }

    pub fn cfg_usize(&self, key: &str) -> Result<usize> {
        self.config
            .get(key)
            .with_context(|| format!("model {}: missing config {key:?}", self.name))?
            .as_usize()
    }

    pub fn batch_size(&self) -> usize {
        self.cfg_usize("batch_size").unwrap_or(0)
    }

    pub fn sparse_params(&self) -> Vec<&ParamSpec> {
        self.params.iter().filter(|p| p.sparse).collect()
    }

    pub fn total_params(&self) -> usize {
        self.params.iter().map(|p| p.shape.numel()).sum()
    }
}

/// The parsed manifest.
#[derive(Clone, Debug)]
pub struct Manifest {
    pub dir: PathBuf,
    pub models: BTreeMap<String, ModelEntry>,
}

impl Manifest {
    pub fn load(dir: impl AsRef<Path>) -> Result<Manifest> {
        let dir = dir.as_ref().to_path_buf();
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {path:?} (run `make artifacts`)"))?;
        let root = Json::parse(&text).context("parsing manifest.json")?;
        if root.get("format")?.as_usize()? != 1 {
            bail!("unsupported manifest format");
        }
        let mut models = BTreeMap::new();
        for (name, entry) in root.get("models")?.as_obj()? {
            models.insert(
                name.clone(),
                parse_model(name, entry, &dir)
                    .with_context(|| format!("model {name:?}"))?,
            );
        }
        Ok(Manifest { dir, models })
    }

    pub fn model(&self, name: &str) -> Result<&ModelEntry> {
        self.models.get(name).with_context(|| {
            format!(
                "unknown model {name:?}; available: {:?}",
                self.models.keys().collect::<Vec<_>>()
            )
        })
    }
}

fn parse_model(name: &str, v: &Json, dir: &Path) -> Result<ModelEntry> {
    let params = v
        .get("params")?
        .as_arr()?
        .iter()
        .map(parse_param)
        .collect::<Result<Vec<_>>>()?;
    let optimizer = match v.get("optimizer")?.as_str()? {
        "sgd" => Optimizer::Sgd,
        "adam" => Optimizer::Adam,
        o => bail!("unknown optimizer {o:?}"),
    };
    let arts = v.get("artifacts")?;
    Ok(ModelEntry {
        name: name.to_string(),
        kind: v.get("kind")?.as_str()?.to_string(),
        optimizer,
        params,
        train: parse_artifact(arts.get("train")?, dir)?,
        eval: parse_artifact(arts.get("eval")?, dir)?,
        grad_norms: parse_artifact(arts.get("grad_norms")?, dir)?,
        replication: parse_replication(v, dir)
            .context("replication artifacts")?,
        config: v.get("config")?.as_obj()?.clone(),
    })
}

/// The optional `"replication"` block — absent in manifests built
/// without `--replicas` (and in all pre-existing ones).
fn parse_replication(v: &Json, dir: &Path) -> Result<Option<ReplicationSpec>> {
    let Ok(rep) = v.get("replication") else {
        return Ok(None);
    };
    let replicas = rep.get("replicas")?.as_usize()?;
    if replicas == 0 {
        bail!("replication block declares zero replicas");
    }
    // new manifests carry one grad artifact per replica (unequal
    // tree-aligned shards); legacy single-"grad" manifests predate
    // remainder sharding, where every shard was the same size — the
    // one artifact serves all replicas
    let grads = if let Ok(arr) = rep.get("grads") {
        let grads = arr
            .as_arr()?
            .iter()
            .map(|g| parse_artifact(g, dir))
            .collect::<Result<Vec<_>>>()?;
        if grads.len() != replicas {
            bail!(
                "replication block declares {} grad artifacts for {replicas} \
                 replicas",
                grads.len()
            );
        }
        grads
    } else {
        vec![parse_artifact(rep.get("grad")?, dir)?; replicas]
    };
    Ok(Some(ReplicationSpec {
        replicas,
        grads,
        apply: parse_artifact(rep.get("apply")?, dir)?,
    }))
}

fn parse_param(v: &Json) -> Result<ParamSpec> {
    let dims: Vec<usize> = v
        .get("shape")?
        .as_arr()?
        .iter()
        .map(|d| d.as_usize())
        .collect::<Result<_>>()?;
    Ok(ParamSpec {
        name: v.get("name")?.as_str()?.to_string(),
        shape: Shape(dims),
        init: InitKind::parse(v.get("init")?.as_str()?)?,
        init_scale: v.get("init_scale")?.as_f64()? as f32,
        sparse: v.get("sparse")?.as_bool()?,
        mac: v.get("mac")?.as_f64()? as u64,
    })
}

fn parse_io(v: &Json) -> Result<IoSpec> {
    let dims: Vec<usize> = v
        .get("shape")?
        .as_arr()?
        .iter()
        .map(|d| d.as_usize())
        .collect::<Result<_>>()?;
    Ok(IoSpec {
        name: v.get("name")?.as_str()?.to_string(),
        shape: Shape(dims),
        dtype: match v.get("dtype")?.as_str()? {
            "f32" => Dtype::F32,
            "i32" => Dtype::I32,
            d => bail!("unknown dtype {d:?}"),
        },
    })
}

fn parse_artifact(v: &Json, dir: &Path) -> Result<ArtifactSpec> {
    Ok(ArtifactSpec {
        file: dir.join(v.get("file")?.as_str()?),
        inputs: v
            .get("inputs")?
            .as_arr()?
            .iter()
            .map(parse_io)
            .collect::<Result<_>>()?,
        outputs: v
            .get("outputs")?
            .as_arr()?
            .iter()
            .map(parse_io)
            .collect::<Result<_>>()?,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn art_dir() -> PathBuf {
        PathBuf::from(concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts"))
    }

    #[test]
    fn loads_real_manifest() {
        let Ok(man) = Manifest::load(art_dir()) else {
            eprintln!("skipping: artifacts not built");
            return;
        };
        assert!(man.models.len() >= 5);
        let lm = man.model("lm_tiny").unwrap();
        assert_eq!(lm.kind, "lm");
        assert_eq!(lm.optimizer, Optimizer::Adam);
        assert!(lm.total_params() > 50_000);
        assert!(!lm.sparse_params().is_empty());
        // train IO convention: params + 2*masks + slots*params + x,y + 4 scalars
        let np = lm.params.len();
        let ns = lm.sparse_params().len();
        assert_eq!(
            lm.train.inputs.len(),
            np + 2 * ns + lm.optimizer.slots() * np + 2 + 4
        );
        assert_eq!(lm.train.outputs.last().unwrap().name, "loss");
        // artifacts exist on disk
        assert!(lm.train.file.exists());
        assert!(lm.eval.file.exists());
        assert!(lm.grad_norms.file.exists());
    }

    #[test]
    fn unknown_model_errors() {
        if let Ok(man) = Manifest::load(art_dir()) {
            assert!(man.model("nope").is_err());
        }
    }

    fn layout_fixture(np: usize, ns: usize, slots: usize) -> ModelEntry {
        let params: Vec<ParamSpec> = (0..np)
            .map(|i| ParamSpec {
                name: format!("p{i}"),
                shape: Shape::new(&[4]),
                init: InitKind::Zeros,
                init_scale: 0.0,
                sparse: i < ns,
                mac: 0,
            })
            .collect();
        let io = |n: usize| -> Vec<IoSpec> {
            (0..n)
                .map(|i| IoSpec {
                    name: format!("io{i}"),
                    shape: Shape::new(&[4]),
                    dtype: Dtype::F32,
                })
                .collect()
        };
        let train = ArtifactSpec {
            file: PathBuf::from("<train>"),
            inputs: io(np + 2 * ns + np * slots + 6),
            outputs: io(np + np * slots + 1),
        };
        let eval = ArtifactSpec {
            file: PathBuf::from("<eval>"),
            inputs: io(np + ns + 2),
            outputs: io(2),
        };
        ModelEntry {
            name: "fixture".into(),
            kind: "mlp".into(),
            optimizer: if slots == 2 { Optimizer::Adam } else { Optimizer::Sgd },
            params,
            train,
            eval: eval.clone(),
            grad_norms: eval,
            replication: None,
            config: BTreeMap::new(),
        }
    }

    #[test]
    fn train_layout_groups_follow_the_io_convention() {
        let m = layout_fixture(3, 2, 2);
        let l = m.train_layout().unwrap();
        assert_eq!(l.params, 0..3);
        assert_eq!(l.masks_fwd, 3..5);
        assert_eq!(l.masks_bwd, 5..7);
        assert_eq!(l.opt, 7..13);
        assert_eq!(l.batch, 13..15);
        assert_eq!(l.scalars, 15..19);
        assert_eq!(l.scalars.end, m.train.inputs.len());
        assert_eq!(l.out_params, 0..3);
        assert_eq!(l.out_opt, 3..9);
        assert_eq!(l.out_loss, 9);
        assert_eq!(l.out_loss + 1, m.train.outputs.len());
    }

    #[test]
    fn eval_layout_covers_eval_and_grad_norms() {
        let m = layout_fixture(3, 2, 1);
        let l = m.eval_layout(&m.eval).unwrap();
        assert_eq!(l.params, 0..3);
        assert_eq!(l.masks_fwd, 3..5);
        assert_eq!(l.batch, 5..7);
        assert!(m.eval_layout(&m.grad_norms).is_ok());
    }

    #[test]
    fn replicated_layout_keys_buffers_by_replica_and_tensor() {
        let m = layout_fixture(3, 2, 2);
        let l = m.replicated_layout(4).unwrap();
        let per = l.inputs_per_replica();
        assert_eq!(per, m.train.inputs.len());
        assert_eq!(l.total_inputs(), 4 * per);
        // replica-major, slot-minor: the same tensor on two replicas
        // maps to two distinct flat slots
        assert_eq!(l.input_index(0, 0), 0);
        assert_eq!(l.input_index(1, 0), per);
        assert_ne!(l.input_index(0, 5), l.input_index(1, 5));
        for flat in [0, per - 1, per, 3 * per + 7] {
            let (r, slot) = l.owner(flat);
            assert_eq!(l.input_index(r, slot), flat, "round-trip at {flat}");
        }
        assert!(m.replicated_layout(0).is_err());
    }

    #[test]
    fn replication_block_is_optional_and_parses_when_present() {
        let art = r#"{"file": "m.hlo.txt", "inputs": [], "outputs": []}"#;
        let without = format!(
            r#"{{"kind": "mlp", "optimizer": "sgd", "params": [], "config": {{}},
                "artifacts": {{"train": {art}, "eval": {art},
                               "grad_norms": {art}}}}}"#
        );
        let m = parse_model("m", &Json::parse(&without).unwrap(), Path::new("a"))
            .unwrap();
        assert!(m.replication.is_none());

        let payload = r#"{"file": "m.grad.hlo.txt",
            "inputs": [{"name": "x", "shape": [2, 8], "dtype": "f32"},
                       {"name": "y", "shape": [2], "dtype": "i32"}],
            "outputs": [{"name": "gsum", "shape": [40], "dtype": "f32"},
                        {"name": "loss_sum", "shape": [1], "dtype": "f32"}]}"#;
        // legacy single-"grad" block: one equal-shard artifact,
        // replicated across every replica slot
        let with = format!(
            r#"{{"kind": "mlp", "optimizer": "sgd", "params": [], "config": {{}},
                "artifacts": {{"train": {art}, "eval": {art},
                               "grad_norms": {art}}},
                "replication": {{"replicas": 2, "grad": {payload},
                                 "apply": {art}}}}}"#
        );
        let m =
            parse_model("m", &Json::parse(&with).unwrap(), Path::new("a")).unwrap();
        let rep = m.replication.unwrap();
        assert_eq!(rep.replicas, 2);
        assert_eq!(rep.grads.len(), 2);
        for grad in &rep.grads {
            assert_eq!(grad.file, Path::new("a").join("m.grad.hlo.txt"));
            assert_eq!(grad.inputs.len(), 2);
            assert_eq!(grad.outputs[0].name, "gsum");
            assert_eq!(grad.outputs[0].shape.numel(), 40);
        }
        assert_eq!(rep.apply.file, Path::new("a").join("m.hlo.txt"));

        // per-replica "grads" array: unequal shards, one entry each
        let with_grads = format!(
            r#"{{"kind": "mlp", "optimizer": "sgd", "params": [], "config": {{}},
                "artifacts": {{"train": {art}, "eval": {art},
                               "grad_norms": {art}}},
                "replication": {{"replicas": 2, "grads": [{payload}, {payload}],
                                 "apply": {art}}}}}"#
        );
        let m = parse_model("m", &Json::parse(&with_grads).unwrap(), Path::new("a"))
            .unwrap();
        assert_eq!(m.replication.unwrap().grads.len(), 2);

        // grads arity must match the declared replica count
        let mismatched = format!(
            r#"{{"kind": "mlp", "optimizer": "sgd", "params": [], "config": {{}},
                "artifacts": {{"train": {art}, "eval": {art},
                               "grad_norms": {art}}},
                "replication": {{"replicas": 3, "grads": [{payload}, {payload}],
                                 "apply": {art}}}}}"#
        );
        let err = parse_model("m", &Json::parse(&mismatched).unwrap(), Path::new("a"))
            .unwrap_err();
        assert!(format!("{err:#}").contains("grad artifacts"), "{err:#}");
    }

    #[test]
    fn layout_rejects_arity_mismatch() {
        let mut m = layout_fixture(3, 2, 1);
        m.train.inputs.pop();
        assert!(m.train_layout().is_err());
        m.eval.inputs.pop();
        assert!(m.eval_layout(&m.eval.clone()).is_err());
    }
}
