//! Device-resident training state — the paper's §2.4 deployment story
//! applied to our own runtime traffic.
//!
//! # Protocol
//!
//! Top-KAST keeps the dense θ on the *host* and recomputes Top-K masks
//! only every N steps (Appendix C: N=100 matches N=1). Everything the
//! accelerator needs between refreshes — parameters, optimiser slots,
//! and the frozen masks — therefore never has to leave the device.
//! [`DeviceState`] owns those tensors as persistent `PjRtBuffer`s and
//! drives the train artifact buffer-in/buffer-out
//! ([`Executable::run_device`]): step N's output buffers become step
//! N+1's input buffers with zero host involvement, and the only
//! per-step transfers are the batch + step scalars up and the loss
//! scalar down.
//!
//! # Sync points — the compact (O(nnz)) exchange plane
//!
//! Host↔device synchronisation happens exactly where the paper needs
//! weights on the CPU, and nowhere else — and what moves is
//! proportional to the *active* set, not the dense model:
//!
//! * **mask refresh** (every `refresh_every` steps, or when the §2.4
//!   async worker needs a fresh snapshot): θ values at the installed
//!   fwd∪bwd sets device→host
//!   ([`DeviceState::sync_active_params_to_host`] — O(nnz); the
//!   optimiser slots stay resident, and positions outside B are
//!   bit-identical on both sides because the train artifacts mask the
//!   update with m_bwd), host Top-K, then only the **index deltas**
//!   host→device ([`DeviceState::upload_mask_deltas`] — O(Δnnz) per
//!   replica, installed with the simulated scatter path
//!   `PjRtBuffer::scatter_mask_update`) — plus, when the strategy
//!   rewrote weights (SET/RigL re-init grown connections, declared via
//!   `MaskStrategy::mutates_weights`), only the recorded **value
//!   edits** host→device
//!   ([`DeviceState::upload_sparse_value_edits`] — O(|edits|) per
//!   replica, 4·Δindices + 4·Δvalues, never the dense 4·n re-upload);
//! * **eval / grad_norms**: no sync at all — both artifacts read the
//!   *resident* param/mask buffers and stream only the batch
//!   ([`DeviceState::run_with_fwd_masks`]);
//! * **checkpoint capture** and **end of run**: full params+opt
//!   device→host so the host store is authoritative again (once per
//!   run, the one remaining dense transfer);
//! * **checkpoint restore** / external mask surgery: full host→device
//!   re-upload (masks as index installs,
//!   [`DeviceState::upload_masks`]).
//!
//! The host `ParamStore` stays the *mask authority* at all times (masks
//! are computed there and pushed down); between syncs its weight values
//! are stale by design, and its dense (non-sparse) tensors stay stale
//! through refreshes too — nothing on the refresh path reads them.
//! [`TrafficModel`] is the analytic traffic account (resident vs
//! streamed vs refresh bytes, sparse vs legacy-dense) that the bench
//! `step_traffic`/`sparse_exchange` scenarios and the transfer-counting
//! tests check against the runtime's real counters.

use anyhow::{bail, Context, Result};

use super::backend::{AnyBackend, Backend, BufferOps};
use super::client::{DeviceInput, Executable, TensorRef};
use super::manifest::{EvalLayout, ModelEntry, TrainLayout};
use crate::sparsity::strategy::Densities;
use crate::sparsity::topk::k_for_density;
use crate::sparsity::ParamStore;
use crate::tensor::{HostTensor, SparseSet, SparseSlice};

/// Persistent device buffers for one model's training state, pinned to
/// one simulated device (a data-parallel run holds one per replica —
/// see `runtime::replicated`). Generic over the [`Backend`]; buffer
/// ownership follows the donation contract in `runtime::backend` —
/// step N's θ/opt are *donated* into step N+1 (never reused), masks
/// are borrowed per step and consumed only by refresh scatters.
pub struct DeviceState<B: Backend = AnyBackend> {
    client: B,
    /// The device every buffer of this state lives on.
    device: usize,
    layout: TrainLayout,
    eval_layout: EvalLayout,
    /// Row-major dims per param (upload shapes), spec order.
    param_dims: Vec<Vec<usize>>,
    /// Positions of sparse params within spec order (mask ordering).
    sparse_idx: Vec<usize>,
    params: Vec<B::Buffer>,
    masks_fwd: Vec<B::Buffer>,
    masks_bwd: Vec<B::Buffer>,
    opt: Vec<B::Buffer>,
    /// Host-side record of the index sets currently expanded into
    /// `masks_fwd`/`masks_bwd` (one (fwd, bwd) pair per sparse tensor,
    /// `sparse_idx` order). The delta base for refresh broadcasts and
    /// the gather driver for active-θ syncs; bookkeeping only — no
    /// traffic.
    installed_masks: Vec<(SparseSet, SparseSet)>,
}

impl<B: Backend> DeviceState<B> {
    /// Build the resident state on device 0 and upload the initial
    /// host state.
    pub fn from_host(
        client: B,
        model: &ModelEntry,
        store: &ParamStore,
        opt: &[Vec<f32>],
    ) -> Result<DeviceState<B>> {
        Self::from_host_on(client, model, store, opt, 0)
    }

    /// Build the resident state on a specific device (one replica of a
    /// data-parallel set).
    pub fn from_host_on(
        client: B,
        model: &ModelEntry,
        store: &ParamStore,
        opt: &[Vec<f32>],
        device: usize,
    ) -> Result<DeviceState<B>> {
        if device >= client.device_count() {
            bail!(
                "device {device} out of range: client has {} simulated device(s)",
                client.device_count()
            );
        }
        let layout = model.train_layout()?;
        let eval_layout = model.eval_layout(&model.eval)?;
        // grad_norms shares the eval input convention; validate now so
        // a mismatched artifact fails at construction, not mid-run.
        let gn_layout = model.eval_layout(&model.grad_norms)?;
        if gn_layout != eval_layout {
            bail!("model {}: eval/grad_norms layouts diverge", model.name);
        }
        let param_dims: Vec<Vec<usize>> =
            model.params.iter().map(|p| p.shape.dims().to_vec()).collect();
        let sparse_idx: Vec<usize> = model
            .params
            .iter()
            .enumerate()
            .filter(|(_, p)| p.sparse)
            .map(|(i, _)| i)
            .collect();
        let mut state = DeviceState {
            client,
            device,
            layout,
            eval_layout,
            param_dims,
            sparse_idx,
            params: vec![],
            masks_fwd: vec![],
            masks_bwd: vec![],
            opt: vec![],
            installed_masks: vec![],
        };
        state.upload_params(store)?;
        state.upload_masks(store)?;
        state.upload_opt(opt)?;
        Ok(state)
    }

    /// The simulated device this state is resident on.
    pub fn device(&self) -> usize {
        self.device
    }

    fn upload_f32(&self, data: &[f32], dims: &[usize]) -> Result<B::Buffer> {
        self.client.buffer_from_host_buffer::<f32>(data, dims, Some(self.device))
    }

    /// Push the host store's dense values down (init, restore).
    pub fn upload_params(&mut self, store: &ParamStore) -> Result<()> {
        self.params = store
            .entries
            .iter()
            .zip(&self.param_dims)
            .map(|(e, dims)| self.upload_f32(&e.values, dims))
            .collect::<Result<_>>()?;
        Ok(())
    }

    /// Push only the *sparse* tensors' dense values down — the refresh
    /// path for weight-rewriting strategies (SET/RigL). The host's
    /// dense (non-sparse) tensors are stale between full syncs, so a
    /// full `upload_params` here would clobber trained state; the
    /// sparse tensors' host values are exact after the active-θ sync.
    pub fn upload_sparse_params(&mut self, store: &ParamStore) -> Result<()> {
        if store.entries.len() != self.params.len() {
            bail!(
                "store has {} params, device {}",
                store.entries.len(),
                self.params.len()
            );
        }
        for &i in &self.sparse_idx {
            let e = &store.entries[i];
            #[cfg(debug_assertions)]
            debug_assert_untouched_match_init(store, i, &e.values, "host store");
            self.params[i] = self.upload_f32(&e.values, &self.param_dims[i])?;
        }
        Ok(())
    }

    /// Apply recorded per-tensor weight edits (`sparse_idx` order) to
    /// the resident sparse params — the O(|edits|) refresh path for
    /// weight-rewriting strategies (SET/RigL). Each non-empty slice
    /// crosses the bus as indices + values (4·|idx| + 4·|vals| bytes)
    /// through the metered scatter; empty slices move nothing. Edits
    /// carry absolute values, so replaying them (fault retry) is
    /// idempotent.
    pub fn upload_sparse_value_edits(&mut self, edits: &[SparseSlice]) -> Result<()> {
        if edits.len() != self.sparse_idx.len() {
            bail!(
                "{} edit slices for {} sparse tensors",
                edits.len(),
                self.sparse_idx.len()
            );
        }
        for (pos, &i) in self.sparse_idx.iter().enumerate() {
            let slice = &edits[pos];
            if slice.is_empty() {
                continue;
            }
            // the scatter *consumes* the old param buffer (donation)
            // and yields its replacement
            let cur = self.params.remove(i);
            self.params.insert(
                i,
                cur.scatter_values_update(slice.indices.indices(), &slice.values)?,
            );
        }
        Ok(())
    }

    /// Install the host store's masks wholesale (construction, restore,
    /// external surgery with no usable delta base). Each mask crosses
    /// the simulated bus as its index list — O(nnz), not O(n) — and is
    /// expanded into the dense resident 0/1 buffer device-side.
    pub fn upload_masks(&mut self, store: &ParamStore) -> Result<()> {
        let mut fwd = Vec::with_capacity(self.sparse_idx.len());
        let mut bwd = Vec::with_capacity(self.sparse_idx.len());
        let mut installed = Vec::with_capacity(self.sparse_idx.len());
        for &i in &self.sparse_idx {
            let e = &store.entries[i];
            let m = e
                .masks
                .as_ref()
                .with_context(|| format!("sparse param {} has no masks", e.spec.name))?;
            let dims = &self.param_dims[i];
            fwd.push(self.client.mask_from_indices(
                dims,
                m.fwd().indices(),
                Some(self.device),
            )?);
            bwd.push(self.client.mask_from_indices(
                dims,
                m.bwd().indices(),
                Some(self.device),
            )?);
            installed.push((m.fwd().clone(), m.bwd().clone()));
        }
        self.masks_fwd = fwd;
        self.masks_bwd = bwd;
        self.installed_masks = installed;
        Ok(())
    }

    /// Refresh install: ship only the index *deltas* between the
    /// currently installed sets and the store's new masks — O(Δnnz)
    /// host→device — and apply them with the metered scatter path.
    /// Unchanged masks move nothing at all.
    pub fn upload_mask_deltas(&mut self, store: &ParamStore) -> Result<()> {
        if self.installed_masks.len() != self.sparse_idx.len() {
            // no delta base (shouldn't happen after construction) —
            // fall back to a full install
            return self.upload_masks(store);
        }
        for (pos, &i) in self.sparse_idx.iter().enumerate() {
            let e = &store.entries[i];
            let m = e
                .masks
                .as_ref()
                .with_context(|| format!("sparse param {} has no masks", e.spec.name))?;
            let (old_fwd, old_bwd) = &self.installed_masks[pos];
            let df = old_fwd.delta_to(m.fwd());
            if !df.is_empty() {
                // the scatter *consumes* the old mask buffer (donation)
                // and yields its replacement
                let cur = self.masks_fwd.remove(pos);
                self.masks_fwd
                    .insert(pos, cur.scatter_mask_update(&df.added, &df.removed)?);
            }
            let db = old_bwd.delta_to(m.bwd());
            if !db.is_empty() {
                let cur = self.masks_bwd.remove(pos);
                self.masks_bwd
                    .insert(pos, cur.scatter_mask_update(&db.added, &db.removed)?);
            }
            self.installed_masks[pos] = (m.fwd().clone(), m.bwd().clone());
        }
        Ok(())
    }

    /// The index sets currently installed on the device for one sparse
    /// tensor (`sparse_idx` order) — tests use this to compute expected
    /// delta traffic independently.
    pub fn installed_masks(&self, pos: usize) -> &(SparseSet, SparseSet) {
        &self.installed_masks[pos]
    }

    /// Install explicit index sets wholesale (`sparse_idx` order) — the
    /// journal-replay path of crash recovery (`runtime::fault`), where
    /// the sets to install are historical rather than the store's
    /// current masks. Same O(nnz) index-list transfer as
    /// `upload_masks`.
    pub fn install_mask_sets(&mut self, sets: &[(SparseSet, SparseSet)]) -> Result<()> {
        if sets.len() != self.sparse_idx.len() {
            bail!(
                "mask set count {} != sparse tensor count {}",
                sets.len(),
                self.sparse_idx.len()
            );
        }
        let mut fwd = Vec::with_capacity(self.sparse_idx.len());
        let mut bwd = Vec::with_capacity(self.sparse_idx.len());
        for (pos, &i) in self.sparse_idx.iter().enumerate() {
            let dims = &self.param_dims[i];
            let (f, b) = &sets[pos];
            fwd.push(self.client.mask_from_indices(
                dims,
                f.indices(),
                Some(self.device),
            )?);
            bwd.push(self.client.mask_from_indices(
                dims,
                b.indices(),
                Some(self.device),
            )?);
        }
        self.masks_fwd = fwd;
        self.masks_bwd = bwd;
        self.installed_masks = sets.to_vec();
        Ok(())
    }

    /// Push host optimiser slots down (init and checkpoint restore).
    pub fn upload_opt(&mut self, opt: &[Vec<f32>]) -> Result<()> {
        let slots = self.layout.opt.len() / self.param_dims.len().max(1);
        if opt.len() != self.layout.opt.len() {
            bail!(
                "opt slot count {} != layout {}",
                opt.len(),
                self.layout.opt.len()
            );
        }
        self.opt = opt
            .iter()
            .enumerate()
            .map(|(j, slot)| {
                // slots are param-major: param j/slots, slot j%slots
                let dims = &self.param_dims[j / slots.max(1)];
                self.upload_f32(slot, dims)
            })
            .collect::<Result<_>>()?;
        Ok(())
    }

    /// Refresh sync: download only the θ values at each sparse tensor's
    /// installed fwd∪bwd set — O(nnz) device→host — and scatter them
    /// into the host store. Exact, not approximate: the train artifacts
    /// mask the update with m_bwd (pinned by the mask-respecting
    /// tests), so every position outside the installed sets is
    /// bit-identical on host and device already. Dense (non-sparse)
    /// tensors are not touched — nothing on the refresh path reads
    /// them.
    pub fn sync_active_params_to_host(&self, store: &mut ParamStore) -> Result<()> {
        if store.entries.len() != self.params.len() {
            bail!(
                "store has {} params, device {}",
                store.entries.len(),
                self.params.len()
            );
        }
        for (pos, &i) in self.sparse_idx.iter().enumerate() {
            let (fwd, bwd) = &self.installed_masks[pos];
            let union = fwd.union(bwd);
            if union.is_empty() {
                continue;
            }
            let values = self.params[i].gather_to_host(union.indices())?;
            let entry = &mut store.entries[i];
            if union.domain() != entry.values.len() {
                bail!("param {} size drifted on device", entry.spec.name);
            }
            union.scatter(&values, &mut entry.values);
            // the O(nnz) sync is exact only because the train artifacts
            // mask the update with m_bwd; if a future graph writes
            // outside the masks, the device copy drifts from init at
            // untouched positions and this check fails loudly instead
            // of silently corrupting parity
            #[cfg(debug_assertions)]
            if let Some(device_values) = self.params[i].debug_read_f32() {
                debug_assert_untouched_match_init(store, i, &device_values, "device");
            }
        }
        Ok(())
    }

    /// Download the dense θ into the host store — the full sync used at
    /// checkpoint capture and end of run (refreshes use the O(nnz)
    /// [`DeviceState::sync_active_params_to_host`] instead).
    pub fn sync_params_to_host(&self, store: &mut ParamStore) -> Result<()> {
        if store.entries.len() != self.params.len() {
            bail!(
                "store has {} params, device {}",
                store.entries.len(),
                self.params.len()
            );
        }
        for (entry, buf) in store.entries.iter_mut().zip(&self.params) {
            let values = buf.to_literal_sync()?.to_vec::<f32>()?;
            if values.len() != entry.values.len() {
                bail!("param {} size drifted on device", entry.spec.name);
            }
            entry.values = values;
        }
        Ok(())
    }

    /// Download the optimiser slots (checkpoint / end-of-run sync).
    pub fn sync_opt_to_host(&self, opt: &mut [Vec<f32>]) -> Result<()> {
        if opt.len() != self.opt.len() {
            bail!("opt slot count {} != device {}", opt.len(), self.opt.len());
        }
        for (dst, buf) in opt.iter_mut().zip(&self.opt) {
            let values = buf.to_literal_sync()?.to_vec::<f32>()?;
            if values.len() != dst.len() {
                bail!("opt slot size drifted on device");
            }
            *dst = values;
        }
        Ok(())
    }

    /// Full device→host sync (params + optimiser slots).
    pub fn sync_to_host(
        &self,
        store: &mut ParamStore,
        opt: &mut [Vec<f32>],
    ) -> Result<()> {
        self.sync_params_to_host(store)?;
        self.sync_opt_to_host(opt)
    }

    /// Distribute a train/apply execution's outputs into the resident
    /// state — the ownership-transferring half of the chaining
    /// protocol: step N's output buffers *become* step N+1's θ/opt
    /// without a clone, and the owned loss buffer is handed back.
    fn chain_outputs(&mut self, outs: Vec<B::Buffer>) -> Result<B::Buffer> {
        let mut params = Vec::with_capacity(self.layout.out_params.len());
        let mut opt = Vec::with_capacity(self.layout.out_opt.len());
        let mut loss = None;
        for (i, buf) in outs.into_iter().enumerate() {
            if self.layout.out_params.contains(&i) {
                params.push(buf);
            } else if self.layout.out_opt.contains(&i) {
                opt.push(buf);
            } else if i == self.layout.out_loss {
                loss = Some(buf);
            }
            // anything else is dropped — frees the device memory
        }
        if params.len() != self.layout.out_params.len()
            || opt.len() != self.layout.out_opt.len()
        {
            bail!(
                "train outputs missing param/opt positions (layout expects \
                 {}+{}, got {}+{})",
                self.layout.out_params.len(),
                self.layout.out_opt.len(),
                params.len(),
                opt.len()
            );
        }
        self.params = params;
        self.opt = opt;
        loss.context("train outputs missing the loss position")
    }

    /// One buffer-in/buffer-out training step: resident θ/opt are
    /// *donated* to the execution (step N's memory backs step N+1's
    /// outputs — real-PJRT input donation), masks are borrowed, the
    /// batch + scalars are streamed, output buffers are installed as
    /// the new resident state, and only the loss scalar is downloaded.
    ///
    /// A failed execution leaves this state poisoned (θ/opt were
    /// donated either way) — callers treat the error as fatal to the
    /// chain, matching real hardware.
    pub fn train_step(
        &mut self,
        exe: &Executable<B>,
        x: TensorRef<'_>,
        y: TensorRef<'_>,
        scalars: &[[f32; 1]],
    ) -> Result<f64> {
        if scalars.len() != self.layout.scalars.len() {
            bail!(
                "expected {} step scalars, got {}",
                self.layout.scalars.len(),
                scalars.len()
            );
        }
        let params = std::mem::take(&mut self.params);
        let opt = std::mem::take(&mut self.opt);
        let mut inputs: Vec<DeviceInput<'_, B>> =
            Vec::with_capacity(self.layout.scalars.end);
        for buf in params {
            inputs.push(DeviceInput::Donate(buf));
        }
        for buf in self.masks_fwd.iter().chain(&self.masks_bwd) {
            inputs.push(DeviceInput::Resident(buf));
        }
        for buf in opt {
            inputs.push(DeviceInput::Donate(buf));
        }
        inputs.push(DeviceInput::Host(x));
        inputs.push(DeviceInput::Host(y));
        for s in scalars {
            inputs.push(DeviceInput::Host(TensorRef::F32(&s[..])));
        }
        let outs = exe.run_device_on(inputs, self.device)?;
        let loss_buf = self.chain_outputs(outs)?;
        let loss_io = &exe.spec.outputs[self.layout.out_loss];
        let loss = exe.download(&loss_buf, loss_io)?.as_f32()?[0] as f64;
        Ok(loss)
    }

    /// Replicated-apply step: like [`DeviceState::train_step`], but the
    /// batch input positions carry the all-reduced gradient payload
    /// (owned buffers from `Backend::all_reduce_sum`, donated here)
    /// instead of a host batch. Outputs chain into the resident state
    /// as usual; the loss buffer is returned *undownloaded* so a
    /// replicated caller pays the d2h transfer on one replica only.
    pub fn apply_step(
        &mut self,
        exe: &Executable<B>,
        payload: Vec<B::Buffer>,
        scalars: &[[f32; 1]],
    ) -> Result<B::Buffer> {
        // the apply artifact keeps the train convention for the
        // resident prefix (θ | masks | opt) and the scalar suffix, but
        // its payload slot count is its own: a θ-shaped payload takes
        // more slots than the two batch inputs it replaces
        let expected_payload = exe
            .spec
            .inputs
            .len()
            .checked_sub(self.layout.batch.start + self.layout.scalars.len())
            .context("apply artifact declares fewer inputs than the resident state")?;
        if payload.len() != expected_payload {
            bail!(
                "expected {expected_payload} payload buffers (apply arity {} - \
                 {} resident - {} scalars), got {}",
                exe.spec.inputs.len(),
                self.layout.batch.start,
                self.layout.scalars.len(),
                payload.len()
            );
        }
        if scalars.len() != self.layout.scalars.len() {
            bail!(
                "expected {} step scalars, got {}",
                self.layout.scalars.len(),
                scalars.len()
            );
        }
        let params = std::mem::take(&mut self.params);
        let opt = std::mem::take(&mut self.opt);
        let mut inputs: Vec<DeviceInput<'_, B>> =
            Vec::with_capacity(self.layout.scalars.end);
        for buf in params {
            inputs.push(DeviceInput::Donate(buf));
        }
        for buf in self.masks_fwd.iter().chain(&self.masks_bwd) {
            inputs.push(DeviceInput::Resident(buf));
        }
        for buf in opt {
            inputs.push(DeviceInput::Donate(buf));
        }
        for buf in payload {
            inputs.push(DeviceInput::Donate(buf));
        }
        for s in scalars {
            inputs.push(DeviceInput::Host(TensorRef::F32(&s[..])));
        }
        let outs = exe.run_device_on(inputs, self.device)?;
        self.chain_outputs(outs)
    }

    /// Download the resident params, masks and optimiser slots as raw
    /// vectors. Diagnostics/tests only (metered d2h traffic!) — the
    /// replica-parity suite uses it to prove lockstep across devices.
    #[allow(clippy::type_complexity)]
    pub fn dump_resident(
        &self,
    ) -> Result<(Vec<Vec<f32>>, Vec<Vec<f32>>, Vec<Vec<f32>>, Vec<Vec<f32>>)> {
        let dl = |bufs: &[B::Buffer]| -> Result<Vec<Vec<f32>>> {
            bufs.iter()
                .map(|b| b.to_literal_sync()?.to_vec::<f32>())
                .collect()
        };
        Ok((
            dl(&self.params)?,
            dl(&self.masks_fwd)?,
            dl(&self.masks_bwd)?,
            dl(&self.opt)?,
        ))
    }

    /// Run an eval-convention artifact (eval or grad_norms) against the
    /// resident params + forward masks, streaming only the batch.
    /// Params/masks are *borrowed* (the concurrent-read escape hatch in
    /// the donation contract — the training chain still owns them).
    /// Returns all outputs downloaded (they are scalars for eval,
    /// per-tensor |grad| maps for grad_norms — both refresh-cadence
    /// sized, not per-step).
    pub fn run_with_fwd_masks(
        &self,
        exe: &Executable<B>,
        x: TensorRef<'_>,
        y: TensorRef<'_>,
    ) -> Result<Vec<HostTensor>> {
        let outs = self.run_with_fwd_masks_resident(exe, x, y)?;
        outs.iter()
            .zip(&exe.spec.outputs)
            .map(|(buf, io)| exe.download(buf, io))
            .collect()
    }

    /// [`DeviceState::run_with_fwd_masks`] without the download: the
    /// outputs stay device-resident. The replicated grad path uses this
    /// for eval-convention grad artifacts whose payload feeds the
    /// all-reduce — nothing may cross back to the host.
    pub fn run_with_fwd_masks_resident(
        &self,
        exe: &Executable<B>,
        x: TensorRef<'_>,
        y: TensorRef<'_>,
    ) -> Result<Vec<B::Buffer>> {
        let mut inputs: Vec<DeviceInput<'_, B>> =
            Vec::with_capacity(self.eval_layout.batch.end);
        for buf in &self.params {
            inputs.push(DeviceInput::Resident(buf));
        }
        for buf in &self.masks_fwd {
            inputs.push(DeviceInput::Resident(buf));
        }
        inputs.push(DeviceInput::Host(x));
        inputs.push(DeviceInput::Host(y));
        exe.run_device_on(inputs, self.device)
    }

    /// Run a train-prefix grad artifact (θ | m_fwd | m_bwd | batch
    /// shard) against the resident state, streaming only the shard.
    /// Everything resident — including the *backward* masks the
    /// payload is masked with — is *borrowed* (the training chain
    /// still owns it), and the outputs stay device-resident: they are
    /// the gradient payload the sparse all-reduce exchanges.
    pub fn run_train_prefix_resident(
        &self,
        exe: &Executable<B>,
        x: TensorRef<'_>,
        y: TensorRef<'_>,
    ) -> Result<Vec<B::Buffer>> {
        let mut inputs: Vec<DeviceInput<'_, B>> = Vec::with_capacity(
            self.params.len() + self.masks_fwd.len() + self.masks_bwd.len() + 2,
        );
        for buf in &self.params {
            inputs.push(DeviceInput::Resident(buf));
        }
        for buf in self.masks_fwd.iter().chain(&self.masks_bwd) {
            inputs.push(DeviceInput::Resident(buf));
        }
        inputs.push(DeviceInput::Host(x));
        inputs.push(DeviceInput::Host(y));
        exe.run_device_on(inputs, self.device)
    }
}

/// Debug-only invariant behind the O(nnz) exchange: a position a
/// sparse tensor's masks have never touched must still hold its init
/// value (the train artifacts mask every update with m_bwd). `values`
/// may be the host store's copy or an unmetered device peek; stores
/// assembled by hand (no init seed) skip the check.
#[cfg(debug_assertions)]
fn debug_assert_untouched_match_init(
    store: &ParamStore,
    i: usize,
    values: &[f32],
    side: &str,
) {
    let Some(seed) = store.init_seed() else { return };
    let entry = &store.entries[i];
    let Some(masks) = entry.masks.as_ref() else { return };
    let Ok(init) = store.regenerate_init_values(&entry.spec.name, seed) else {
        return;
    };
    if init.len() != values.len() {
        return; // size drift is reported by the metered paths
    }
    let touched = masks.touched();
    for (j, (&v, &v0)) in values.iter().zip(&init).enumerate() {
        if !touched.contains(j as u32) {
            debug_assert!(
                v.to_bits() == v0.to_bits(),
                "param {}[{j}] ({side}): untouched position drifted from its \
                 init value ({v0} -> {v}) — the update graph wrote outside \
                 m_bwd, which breaks the O(nnz) refresh sync",
                entry.spec.name,
            );
        }
    }
}

/// Analytic traffic account for a model under the device-resident
/// protocol, split three ways: what stays resident, what streams per
/// step, and what a refresh moves under the **compact sparse
/// exchange** (index deltas up, active θ down) vs the **legacy dense
/// exchange** (dense 0/1 masks up, dense θ down) it replaced.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct TrafficModel {
    /// Data-parallel replica count the account is for (1 = the plain
    /// single-device protocol).
    pub replicas: u64,
    /// Bytes parked on *each* device between refreshes (θ + opt +
    /// masks); the replica set holds `replicas ×` this in total.
    pub resident_bytes: u64,
    /// Host→device bytes per steady-state step, total across replicas
    /// (each replica streams its batch shard + its own step scalars).
    pub step_h2d_bytes: u64,
    /// Host→device bytes per steady-state step through *one* replica's
    /// link (its shard + the step scalars). Equals `step_h2d_bytes`
    /// when `replicas == 1`.
    pub replica_step_h2d_bytes: u64,
    /// Interconnect bytes per step for the fixed-order gradient
    /// all-reduce, summed over the replica set (0 when `replicas == 1`
    /// — a lone participant moves nothing). This is the **sparse**
    /// account (equal to `allreduce_sparse_bytes`): payload tensors
    /// classified as bwd-masked gradients travel as gathered on-set
    /// values only.
    pub allreduce_step_bytes: u64,
    /// The sparse all-reduce account per step across the replica set:
    /// a grad output named `g:<sparse-param>` with matching numel
    /// moves 4·|B_t| bytes per replica (its installed bwd set);
    /// unclassified payload (batch-moment scalars) stays dense. Equals
    /// `legacy_allreduce_bytes` at densities 1.0.
    pub allreduce_sparse_bytes: u64,
    /// What the dense all-reduce plane moved per step before the
    /// sparse exchange: 4·numel for every payload tensor, per replica.
    pub legacy_allreduce_bytes: u64,
    /// Device→host bytes per steady-state step (the loss scalar,
    /// downloaded from replica 0 only).
    pub step_d2h_bytes: u64,
    /// Device→host bytes at a mask refresh under the sparse exchange:
    /// θ values at each sparse tensor's installed fwd∪bwd set —
    /// **O(nnz)**, 4·Σ|B_t| for nested strategies — plus the dense
    /// |grad| maps for gradient-guided strategies (RigL's grow
    /// criterion is inherently dense). Replica 0 serves the sync, so
    /// this does not scale with the replica count.
    pub refresh_d2h_bytes: u64,
    /// Host→device bytes of a *full* mask install (construction /
    /// restore / worst-case refresh where the whole set churns):
    /// 4·Σ(|A_t| + |B_t|) index words per replica, plus
    /// `refresh_h2d_fixed_bytes`. A steady refresh moves
    /// [`TrafficModel::refresh_h2d_delta_bytes`] instead — **O(Δnnz)**.
    pub refresh_h2d_install_bytes: u64,
    /// Content-independent part of every refresh upload: the
    /// grad_norms batch on replica 0. Weight-rewriting strategies no
    /// longer contribute here — their refresh ships recorded value
    /// edits, accounted per refresh via
    /// [`TrafficModel::refresh_h2d_edit_bytes`].
    pub refresh_h2d_fixed_bytes: u64,
    /// What the dense exchange plane moved at a refresh before the
    /// sparse protocol: two dense 0/1 f32 masks per sparse tensor per
    /// replica (+ grad_norms batch + full dense params for rewriting
    /// strategies) up…
    pub legacy_refresh_h2d_bytes: u64,
    /// …and the full dense θ down.
    pub legacy_refresh_d2h_bytes: u64,
    /// Device→host bytes of a full sync (checkpoint capture / end of
    /// run): θ + optimiser slots.
    pub checkpoint_d2h_bytes: u64,
    /// What the pre-device-resident loop moved *every step*
    /// (θ + masks + opt up, θ + opt + loss down) — the baseline the
    /// bench trajectory measures against.
    pub legacy_step_bytes: u64,
}

impl TrafficModel {
    /// Build the account from a model's manifest entry, assuming dense
    /// masks (densities 1.0 — the conservative default when no
    /// strategy is in scope). `strategy_rewrites_weights` adds the
    /// sparse-param re-upload that SET/RigL refreshes require;
    /// `strategy_uses_grad_norms` adds the grad_norms pass RigL runs
    /// at each update (one batch up, one dense |grad| tensor per
    /// sparse param down).
    pub fn of(
        model: &ModelEntry,
        strategy_rewrites_weights: bool,
        strategy_uses_grad_norms: bool,
    ) -> Result<Self> {
        Self::replicated(model, strategy_rewrites_weights, strategy_uses_grad_norms, 1)
    }

    /// [`TrafficModel::of`] for an N-replica run (dense-mask densities).
    pub fn replicated(
        model: &ModelEntry,
        strategy_rewrites_weights: bool,
        strategy_uses_grad_norms: bool,
        replicas: usize,
    ) -> Result<Self> {
        Self::with_densities(
            model,
            strategy_rewrites_weights,
            strategy_uses_grad_norms,
            replicas,
            Densities { fwd: 1.0, bwd: 1.0 },
        )
    }

    /// The full account for an N-replica data-parallel run at the
    /// strategy's nominal densities (`replicas = 1` reduces exactly to
    /// the single-device protocol). Per-replica steady state streams
    /// one batch shard + the step scalars up; the gradient payload
    /// (the replication grad artifact's outputs) crosses the
    /// interconnect once per replica per step; a refresh broadcasts
    /// index deltas to every replica while the active-θ download and
    /// the grad_norms batch stay on replica 0.
    ///
    /// Sparse set sizes come from `k_for_density` per tensor — the same
    /// rounding the strategies use — with |B_t| = max(k_bwd, k_fwd)
    /// (every shipped strategy keeps A ⊆ B). Schedule-varying
    /// strategies (pruning) are accounted at the densities passed in.
    pub fn with_densities(
        model: &ModelEntry,
        strategy_rewrites_weights: bool,
        strategy_uses_grad_norms: bool,
        replicas: usize,
        densities: Densities,
    ) -> Result<Self> {
        let layout = model.train_layout()?;
        let p_bytes: u64 =
            model.params.iter().map(|p| 4 * p.shape.numel() as u64).sum();
        let m_bytes: u64 = model
            .sparse_params()
            .iter()
            .map(|p| 4 * p.shape.numel() as u64)
            .sum();
        let p_sparse_bytes = m_bytes; // dense f32 values of the sparse tensors
        let (mut nnz_fwd, mut nnz_bwd) = (0u64, 0u64);
        for p in model.sparse_params() {
            let n = p.shape.numel();
            let ka = k_for_density(n, densities.fwd);
            let kb = k_for_density(n, densities.bwd).max(ka);
            nnz_fwd += ka as u64;
            nnz_bwd += kb as u64;
        }
        let slots = model.optimizer.slots() as u64;
        let batch_bytes: u64 = model.train.inputs[layout.batch.clone()]
            .iter()
            .map(|io| 4 * io.shape.numel() as u64)
            .sum();
        let scalar_bytes = 4 * layout.scalars.len() as u64;
        let loss_bytes = 4u64;
        let grad_norms_h2d = if strategy_uses_grad_norms { batch_bytes } else { 0 };
        let grad_norms_d2h = if strategy_uses_grad_norms { m_bytes } else { 0 };
        let r = replicas.max(1) as u64;
        let (
            step_h2d_bytes,
            replica_step_h2d_bytes,
            allreduce_sparse_bytes,
            legacy_allreduce_bytes,
        ) = if replicas > 1 {
            let rep = model.replication.as_ref().with_context(|| {
                format!(
                    "model {}: traffic account for {replicas} replicas needs \
                     replication artifacts (grad/apply)",
                    model.name
                )
            })?;
            if rep.replicas != replicas {
                bail!(
                    "model {}: replication artifacts were built for {} \
                     replicas, account requested for {replicas}",
                    model.name,
                    rep.replicas
                );
            }
            // per-replica shard streams: the batch convention is the
            // *last two* grad inputs — any θ/mask prefix is resident
            // and never crosses the bus per step. Tree-aligned shards
            // of a non-pow2 split are unequal, so each replica's own
            // artifact sizes its link.
            let mut shards_total = 0u64;
            let mut shard0 = 0u64;
            for (ri, grad) in rep.grads.iter().enumerate() {
                if grad.inputs.len() < 2 {
                    bail!(
                        "model {}: grad artifact {ri} declares {} inputs, \
                         the batch convention needs at least (x, y)",
                        model.name,
                        grad.inputs.len()
                    );
                }
                let bytes: u64 = grad.inputs[grad.inputs.len() - 2..]
                    .iter()
                    .map(|io| 4 * io.shape.numel() as u64)
                    .sum();
                if ri == 0 {
                    shard0 = bytes;
                }
                shards_total += bytes;
            }
            // payload classification (normative — see
            // `runtime::replicated`): a grad output named
            // `g:<sparse-param>` whose numel matches that param rides
            // the sparse all-reduce at the bwd set size; everything
            // else (batch-moment scalars) stays dense
            let mut sparse_payload = 0u64;
            let mut dense_payload = 0u64;
            for io in &rep.grads[0].outputs {
                let numel = io.shape.numel();
                dense_payload += 4 * numel as u64;
                let k_bwd = io.name.strip_prefix("g:").and_then(|pname| {
                    model
                        .sparse_params()
                        .iter()
                        .find(|p| p.name == pname && p.shape.numel() == numel)
                        .map(|p| {
                            let n = p.shape.numel();
                            k_for_density(n, densities.bwd)
                                .max(k_for_density(n, densities.fwd))
                        })
                });
                sparse_payload += 4 * k_bwd.unwrap_or(numel) as u64;
            }
            (
                shards_total + r * scalar_bytes,
                shard0 + scalar_bytes,
                r * sparse_payload,
                r * dense_payload,
            )
        } else {
            (batch_bytes + scalar_bytes, batch_bytes + scalar_bytes, 0, 0)
        };
        // weight-rewriting strategies ship recorded value edits at a
        // refresh (refresh_h2d_edit_bytes), not a dense param re-upload
        let _ = p_sparse_bytes;
        let refresh_h2d_fixed_bytes = grad_norms_h2d;
        Ok(TrafficModel {
            replicas: r,
            resident_bytes: p_bytes * (1 + slots) + 2 * m_bytes,
            step_h2d_bytes,
            replica_step_h2d_bytes,
            allreduce_step_bytes: allreduce_sparse_bytes,
            allreduce_sparse_bytes,
            legacy_allreduce_bytes,
            step_d2h_bytes: loss_bytes,
            refresh_d2h_bytes: 4 * nnz_bwd + grad_norms_d2h,
            refresh_h2d_install_bytes: r * 4 * (nnz_fwd + nnz_bwd)
                + refresh_h2d_fixed_bytes,
            refresh_h2d_fixed_bytes,
            legacy_refresh_h2d_bytes: r * 2 * m_bytes
                + grad_norms_h2d
                + if strategy_rewrites_weights { r * p_bytes } else { 0 },
            legacy_refresh_d2h_bytes: p_bytes + grad_norms_d2h,
            checkpoint_d2h_bytes: p_bytes * (1 + slots),
            legacy_step_bytes: p_bytes * (1 + slots) + 2 * m_bytes
                + batch_bytes
                + scalar_bytes
                + p_bytes * (1 + slots)
                + loss_bytes,
        })
    }

    /// Host→device bytes of a refresh that ships `delta_indices` index
    /// words (Σ per-tensor |added| + |removed| across both masks) —
    /// the broadcast reaches every replica, the fixed part rides along.
    pub fn refresh_h2d_delta_bytes(&self, delta_indices: u64) -> u64 {
        self.replicas * 4 * delta_indices + self.refresh_h2d_fixed_bytes
    }

    /// Host→device bytes of the value edits a weight-rewriting refresh
    /// ships: `edit_entries` (index, value) pairs — 4 bytes of index +
    /// 4 bytes of value each — broadcast to every replica.
    pub fn refresh_h2d_edit_bytes(&self, edit_entries: u64) -> u64 {
        self.replicas * 8 * edit_entries
    }

    /// Mean bytes/step when refreshing every N steps, charging every
    /// refresh at the full-install worst case.
    pub fn amortized_step_bytes(&self, refresh_every: usize) -> f64 {
        let n = refresh_every.max(1) as f64;
        (self.step_h2d_bytes + self.step_d2h_bytes) as f64
            + (self.refresh_d2h_bytes + self.refresh_h2d_install_bytes) as f64 / n
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::synthetic::Synthetic;
    use crate::runtime::Runtime;
    use crate::sparsity::ParamStore;

    #[test]
    fn traffic_model_decouples_steps_from_dense_size() {
        let synth = Synthetic::tiny();
        let t = TrafficModel::of(&synth.model, false, false).unwrap();
        // steady-state traffic is batch-sized, independent of θ
        let dense_bytes: u64 = synth
            .model
            .params
            .iter()
            .map(|p| 4 * p.shape.numel() as u64)
            .sum();
        assert!(t.resident_bytes >= dense_bytes);
        assert!(t.step_h2d_bytes < dense_bytes);
        assert_eq!(t.step_d2h_bytes, 4);
        assert!(t.legacy_step_bytes > t.step_h2d_bytes + t.step_d2h_bytes);
        // amortisation approaches the steady-state floor as N grows
        let floor = (t.step_h2d_bytes + t.step_d2h_bytes) as f64;
        assert!(t.amortized_step_bytes(1) > t.amortized_step_bytes(100));
        assert!(t.amortized_step_bytes(1_000_000) - floor < 1.0 + floor * 1e-3);
        // grad-norms strategies (RigL) pay one batch up + one dense
        // |grad| per sparse tensor down at each refresh
        let g = TrafficModel::of(&synth.model, true, true).unwrap();
        assert!(g.refresh_d2h_bytes > t.refresh_d2h_bytes);
        assert!(g.refresh_h2d_install_bytes > t.refresh_h2d_install_bytes);
        assert_eq!(g.refresh_h2d_delta_bytes(0), g.refresh_h2d_fixed_bytes);
        assert_eq!(g.step_h2d_bytes, t.step_h2d_bytes, "steady state unchanged");
        // refresh downloads active θ only; a checkpoint syncs the full
        // dense θ plus the optimiser slots
        assert!(t.checkpoint_d2h_bytes > t.refresh_d2h_bytes);
    }

    #[test]
    fn sparse_exchange_account_scales_with_nnz_not_n() {
        let synth = Synthetic::small();
        let dense = TrafficModel::of(&synth.model, false, false).unwrap();
        let mut last_d2h = u64::MAX;
        let mut last_install = u64::MAX;
        for sparsity in [0.8, 0.9, 0.98] {
            let d = 1.0 - sparsity;
            let t = TrafficModel::with_densities(
                &synth.model,
                false,
                false,
                1,
                Densities { fwd: d, bwd: d },
            )
            .unwrap();
            // exact: refresh d2h = 4·Σ k_for_density(n_t, d)
            let want: u64 = synth
                .model
                .sparse_params()
                .iter()
                .map(|p| 4 * k_for_density(p.shape.numel(), d) as u64)
                .sum();
            assert_eq!(t.refresh_d2h_bytes, want);
            assert_eq!(t.refresh_h2d_install_bytes, 2 * want);
            // refresh bytes shrink monotonically with sparsity, and the
            // sparse exchange undercuts the legacy dense one
            assert!(t.refresh_d2h_bytes < last_d2h);
            assert!(t.refresh_h2d_install_bytes < last_install);
            assert!(t.refresh_d2h_bytes < dense.legacy_refresh_d2h_bytes);
            assert!(t.refresh_h2d_install_bytes < dense.legacy_refresh_h2d_bytes);
            // delta accounting: Δ index words broadcast per replica
            assert_eq!(t.refresh_h2d_delta_bytes(10), 40);
            last_d2h = t.refresh_d2h_bytes;
            last_install = t.refresh_h2d_install_bytes;
        }
        // at density 1.0 the index install degenerates to the dense
        // mask cost (u32 index words == f32 mask words)
        assert_eq!(
            dense.refresh_h2d_install_bytes,
            dense.legacy_refresh_h2d_bytes
        );
    }

    #[test]
    fn replicated_traffic_keys_accounting_by_replica() {
        let synth = Synthetic::tiny();
        let base = TrafficModel::of(&synth.model, false, false).unwrap();
        assert_eq!(base.replicas, 1);
        assert_eq!(base.replica_step_h2d_bytes, base.step_h2d_bytes);
        assert_eq!(base.allreduce_step_bytes, 0, "one replica: no interconnect");
        // without replication artifacts, an N-replica account is a
        // clear error, not a silently-wrong single-device number
        assert!(TrafficModel::replicated(&synth.model, false, false, 2).is_err());

        let replicated = synth.replicated(4).unwrap();
        let t = TrafficModel::replicated(&replicated.model, false, false, 4).unwrap();
        assert_eq!(t.replicas, 4);
        // tiny's batch 4 shards equally across 4 replicas
        assert_eq!(t.step_h2d_bytes, 4 * t.replica_step_h2d_bytes);
        // each replica uploads its shard: shard + scalars < full batch + scalars
        assert!(t.replica_step_h2d_bytes < base.step_h2d_bytes);
        // payload = gsum_x + gsum_y + g:w1 (128) + g:w2 (64), once per
        // replica; at densities 1.0 the sparse account degenerates to
        // the dense one
        assert_eq!(t.allreduce_step_bytes, 4 * (4 * (1 + 1 + 128 + 64)));
        assert_eq!(t.allreduce_sparse_bytes, t.allreduce_step_bytes);
        assert_eq!(t.legacy_allreduce_bytes, t.allreduce_sparse_bytes);
        // at real sparsities the gradient exchange is O(nnz): the g:*
        // tensors travel at 4·k_bwd each while the moment scalars and
        // the legacy dense account are unchanged
        let s = TrafficModel::with_densities(
            &replicated.model,
            false,
            false,
            4,
            Densities { fwd: 0.2, bwd: 0.5 },
        )
        .unwrap();
        let k_bwd: u64 = replicated
            .model
            .sparse_params()
            .iter()
            .map(|p| {
                let n = p.shape.numel();
                k_for_density(n, 0.5).max(k_for_density(n, 0.2)) as u64
            })
            .sum();
        assert_eq!(s.allreduce_sparse_bytes, 4 * (4 * 2 + 4 * k_bwd));
        assert_eq!(s.allreduce_step_bytes, s.allreduce_sparse_bytes);
        assert_eq!(s.legacy_allreduce_bytes, t.legacy_allreduce_bytes);
        assert!(s.allreduce_sparse_bytes < s.legacy_allreduce_bytes);
        // refresh: index deltas broadcast to all replicas, θ down from one
        assert_eq!(t.refresh_h2d_install_bytes, 4 * base.refresh_h2d_install_bytes);
        assert_eq!(t.refresh_h2d_delta_bytes(7), 4 * base.refresh_h2d_delta_bytes(7));
        assert_eq!(t.refresh_d2h_bytes, base.refresh_d2h_bytes);
        assert_eq!(t.checkpoint_d2h_bytes, base.checkpoint_d2h_bytes);
        // mismatched replica count is rejected
        assert!(TrafficModel::replicated(&replicated.model, false, false, 2).is_err());
    }

    #[test]
    fn round_trip_through_device_state_preserves_host_state() {
        let synth = Synthetic::tiny();
        let mut rt = Runtime::new().unwrap();
        synth.install(&mut rt).unwrap();
        let store = ParamStore::init(&synth.model.params, 7);
        let slots = synth.model.optimizer.slots();
        let opt: Vec<Vec<f32>> = synth
            .model
            .params
            .iter()
            .flat_map(|p| {
                std::iter::repeat_with(move || vec![0.25f32; p.shape.numel()])
                    .take(slots)
            })
            .collect();
        let dev = DeviceState::from_host(
            rt.client().clone(),
            &synth.model,
            &store,
            &opt,
        )
        .unwrap();
        let mut store2 = ParamStore::init(&synth.model.params, 999);
        let mut opt2: Vec<Vec<f32>> =
            opt.iter().map(|s| vec![0.0; s.len()]).collect();
        dev.sync_to_host(&mut store2, &mut opt2).unwrap();
        for (a, b) in store.entries.iter().zip(&store2.entries) {
            assert_eq!(a.values, b.values);
        }
        assert_eq!(opt, opt2);
    }
}
