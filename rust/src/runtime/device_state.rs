//! Device-resident training state — the paper's §2.4 deployment story
//! applied to our own runtime traffic.
//!
//! # Protocol
//!
//! Top-KAST keeps the dense θ on the *host* and recomputes Top-K masks
//! only every N steps (Appendix C: N=100 matches N=1). Everything the
//! accelerator needs between refreshes — parameters, optimiser slots,
//! and the frozen masks — therefore never has to leave the device.
//! [`DeviceState`] owns those tensors as persistent `PjRtBuffer`s and
//! drives the train artifact buffer-in/buffer-out
//! ([`Executable::run_device`]): step N's output buffers become step
//! N+1's input buffers with zero host involvement, and the only
//! per-step transfers are the batch + step scalars up and the loss
//! scalar down.
//!
//! # Sync points
//!
//! Host↔device synchronisation happens exactly where the paper needs
//! dense weights on the CPU, and nowhere else:
//!
//! * **mask refresh** (every `refresh_every` steps, or when the §2.4
//!   async worker needs a fresh snapshot): the dense θ device→host
//!   ([`DeviceState::sync_params_to_host`] — the optimiser slots stay
//!   resident), host Top-K, then only the new masks host→device
//!   ([`DeviceState::upload_masks`]) — plus params host→device when
//!   the strategy rewrote weights (SET/RigL re-init grown
//!   connections, declared via `MaskStrategy::mutates_weights`);
//! * **eval / grad_norms**: no sync at all — both artifacts read the
//!   *resident* param/mask buffers and stream only the batch
//!   ([`DeviceState::run_with_fwd_masks`]);
//! * **checkpoint capture** and **end of run**: full params+opt
//!   device→host so the host store is authoritative again;
//! * **checkpoint restore** / external mask surgery: full host→device
//!   re-upload.
//!
//! The host `ParamStore` stays the *mask authority* at all times (masks
//! are computed there and pushed down); between syncs its weight values
//! are stale by design. [`TrafficModel`] is the analytic per-step
//! traffic account (resident vs streamed bytes) that the bench
//! `step_traffic` scenario and the transfer-counting tests check
//! against the runtime's real counters.

use anyhow::{bail, Context, Result};

use super::client::{DeviceInput, Executable, TensorRef};
use super::manifest::{EvalLayout, ModelEntry, TrainLayout};
use crate::sparsity::ParamStore;
use crate::tensor::HostTensor;
use crate::xla;

/// Persistent device buffers for one model's training state, pinned to
/// one simulated device (a data-parallel run holds one per replica —
/// see `runtime::replicated`).
pub struct DeviceState {
    client: xla::PjRtClient,
    /// The device every buffer of this state lives on.
    device: usize,
    layout: TrainLayout,
    eval_layout: EvalLayout,
    /// Row-major dims per param (upload shapes), spec order.
    param_dims: Vec<Vec<usize>>,
    /// Positions of sparse params within spec order (mask ordering).
    sparse_idx: Vec<usize>,
    params: Vec<xla::PjRtBuffer>,
    masks_fwd: Vec<xla::PjRtBuffer>,
    masks_bwd: Vec<xla::PjRtBuffer>,
    opt: Vec<xla::PjRtBuffer>,
}

impl DeviceState {
    /// Build the resident state on device 0 and upload the initial
    /// host state.
    pub fn from_host(
        client: xla::PjRtClient,
        model: &ModelEntry,
        store: &ParamStore,
        opt: &[Vec<f32>],
    ) -> Result<DeviceState> {
        Self::from_host_on(client, model, store, opt, 0)
    }

    /// Build the resident state on a specific device (one replica of a
    /// data-parallel set).
    pub fn from_host_on(
        client: xla::PjRtClient,
        model: &ModelEntry,
        store: &ParamStore,
        opt: &[Vec<f32>],
        device: usize,
    ) -> Result<DeviceState> {
        if device >= client.device_count() {
            bail!(
                "device {device} out of range: client has {} simulated device(s)",
                client.device_count()
            );
        }
        let layout = model.train_layout()?;
        let eval_layout = model.eval_layout(&model.eval)?;
        // grad_norms shares the eval input convention; validate now so
        // a mismatched artifact fails at construction, not mid-run.
        let gn_layout = model.eval_layout(&model.grad_norms)?;
        if gn_layout != eval_layout {
            bail!("model {}: eval/grad_norms layouts diverge", model.name);
        }
        let param_dims: Vec<Vec<usize>> =
            model.params.iter().map(|p| p.shape.dims().to_vec()).collect();
        let sparse_idx: Vec<usize> = model
            .params
            .iter()
            .enumerate()
            .filter(|(_, p)| p.sparse)
            .map(|(i, _)| i)
            .collect();
        let mut state = DeviceState {
            client,
            device,
            layout,
            eval_layout,
            param_dims,
            sparse_idx,
            params: vec![],
            masks_fwd: vec![],
            masks_bwd: vec![],
            opt: vec![],
        };
        state.upload_params(store)?;
        state.upload_masks(store)?;
        state.upload_opt(opt)?;
        Ok(state)
    }

    /// The simulated device this state is resident on.
    pub fn device(&self) -> usize {
        self.device
    }

    fn upload_f32(&self, data: &[f32], dims: &[usize]) -> Result<xla::PjRtBuffer> {
        self.client.buffer_from_host_buffer::<f32>(data, dims, Some(self.device))
    }

    /// Push the host store's dense values down (init, restore, or after
    /// a weight-rewriting mask update).
    pub fn upload_params(&mut self, store: &ParamStore) -> Result<()> {
        self.params = store
            .entries
            .iter()
            .zip(&self.param_dims)
            .map(|(e, dims)| self.upload_f32(&e.values, dims))
            .collect::<Result<_>>()?;
        Ok(())
    }

    /// Push the host store's masks down (refresh install points only).
    pub fn upload_masks(&mut self, store: &ParamStore) -> Result<()> {
        let mut fwd = Vec::with_capacity(self.sparse_idx.len());
        let mut bwd = Vec::with_capacity(self.sparse_idx.len());
        for &i in &self.sparse_idx {
            let e = &store.entries[i];
            let m = e
                .masks
                .as_ref()
                .with_context(|| format!("sparse param {} has no masks", e.spec.name))?;
            let dims = &self.param_dims[i];
            fwd.push(self.upload_f32(m.fwd(), dims)?);
            bwd.push(self.upload_f32(m.bwd(), dims)?);
        }
        self.masks_fwd = fwd;
        self.masks_bwd = bwd;
        Ok(())
    }

    /// Push host optimiser slots down (init and checkpoint restore).
    pub fn upload_opt(&mut self, opt: &[Vec<f32>]) -> Result<()> {
        let slots = self.layout.opt.len() / self.param_dims.len().max(1);
        if opt.len() != self.layout.opt.len() {
            bail!(
                "opt slot count {} != layout {}",
                opt.len(),
                self.layout.opt.len()
            );
        }
        self.opt = opt
            .iter()
            .enumerate()
            .map(|(j, slot)| {
                // slots are param-major: param j/slots, slot j%slots
                let dims = &self.param_dims[j / slots.max(1)];
                self.upload_f32(slot, dims)
            })
            .collect::<Result<_>>()?;
        Ok(())
    }

    /// Download the dense θ into the host store — the mask-refresh
    /// sync (host Top-K needs only the weights, not the slots).
    pub fn sync_params_to_host(&self, store: &mut ParamStore) -> Result<()> {
        if store.entries.len() != self.params.len() {
            bail!(
                "store has {} params, device {}",
                store.entries.len(),
                self.params.len()
            );
        }
        for (entry, buf) in store.entries.iter_mut().zip(&self.params) {
            let values = buf.to_literal_sync()?.to_vec::<f32>()?;
            if values.len() != entry.values.len() {
                bail!("param {} size drifted on device", entry.spec.name);
            }
            entry.values = values;
        }
        Ok(())
    }

    /// Download the optimiser slots (checkpoint / end-of-run sync).
    pub fn sync_opt_to_host(&self, opt: &mut [Vec<f32>]) -> Result<()> {
        if opt.len() != self.opt.len() {
            bail!("opt slot count {} != device {}", opt.len(), self.opt.len());
        }
        for (dst, buf) in opt.iter_mut().zip(&self.opt) {
            let values = buf.to_literal_sync()?.to_vec::<f32>()?;
            if values.len() != dst.len() {
                bail!("opt slot size drifted on device");
            }
            *dst = values;
        }
        Ok(())
    }

    /// Full device→host sync (params + optimiser slots).
    pub fn sync_to_host(
        &self,
        store: &mut ParamStore,
        opt: &mut [Vec<f32>],
    ) -> Result<()> {
        self.sync_params_to_host(store)?;
        self.sync_opt_to_host(opt)
    }

    /// One buffer-in/buffer-out training step: resident θ/masks/opt,
    /// streamed batch + scalars, output buffers installed as the new
    /// resident state, and only the loss scalar downloaded.
    pub fn train_step(
        &mut self,
        exe: &Executable,
        x: TensorRef<'_>,
        y: TensorRef<'_>,
        scalars: &[[f32; 1]],
    ) -> Result<f64> {
        if scalars.len() != self.layout.scalars.len() {
            bail!(
                "expected {} step scalars, got {}",
                self.layout.scalars.len(),
                scalars.len()
            );
        }
        let mut inputs: Vec<DeviceInput<'_>> =
            Vec::with_capacity(self.layout.scalars.end);
        for buf in &self.params {
            inputs.push(DeviceInput::Resident(buf));
        }
        for buf in self.masks_fwd.iter().chain(&self.masks_bwd) {
            inputs.push(DeviceInput::Resident(buf));
        }
        for buf in &self.opt {
            inputs.push(DeviceInput::Resident(buf));
        }
        inputs.push(DeviceInput::Host(x));
        inputs.push(DeviceInput::Host(y));
        for s in scalars {
            inputs.push(DeviceInput::Host(TensorRef::F32(&s[..])));
        }
        let outs = exe.run_device_on(&inputs, self.device)?;
        drop(inputs);
        // chain: step-N outputs become step-N+1 resident inputs
        self.params = outs[self.layout.out_params.clone()].to_vec();
        self.opt = outs[self.layout.out_opt.clone()].to_vec();
        let loss_buf = &outs[self.layout.out_loss];
        let loss_io = &exe.spec.outputs[self.layout.out_loss];
        let loss = exe.download(loss_buf, loss_io)?.as_f32()?[0] as f64;
        Ok(loss)
    }

    /// Replicated-apply step: like [`DeviceState::train_step`], but the
    /// batch input positions carry the all-reduced gradient payload
    /// (resident buffers from `PjRtClient::all_reduce_sum`) instead of
    /// a host batch. Outputs chain into the resident state as usual;
    /// the loss buffer is returned *undownloaded* so a replicated
    /// caller pays the d2h transfer on one replica only.
    pub fn apply_step(
        &mut self,
        exe: &Executable,
        payload: &[xla::PjRtBuffer],
        scalars: &[[f32; 1]],
    ) -> Result<xla::PjRtBuffer> {
        if payload.len() != self.layout.batch.len() {
            bail!(
                "expected {} payload buffers (one per batch slot), got {}",
                self.layout.batch.len(),
                payload.len()
            );
        }
        if scalars.len() != self.layout.scalars.len() {
            bail!(
                "expected {} step scalars, got {}",
                self.layout.scalars.len(),
                scalars.len()
            );
        }
        let mut inputs: Vec<DeviceInput<'_>> =
            Vec::with_capacity(self.layout.scalars.end);
        for buf in &self.params {
            inputs.push(DeviceInput::Resident(buf));
        }
        for buf in self.masks_fwd.iter().chain(&self.masks_bwd) {
            inputs.push(DeviceInput::Resident(buf));
        }
        for buf in &self.opt {
            inputs.push(DeviceInput::Resident(buf));
        }
        for buf in payload {
            inputs.push(DeviceInput::Resident(buf));
        }
        for s in scalars {
            inputs.push(DeviceInput::Host(TensorRef::F32(&s[..])));
        }
        let outs = exe.run_device_on(&inputs, self.device)?;
        drop(inputs);
        self.params = outs[self.layout.out_params.clone()].to_vec();
        self.opt = outs[self.layout.out_opt.clone()].to_vec();
        Ok(outs[self.layout.out_loss].clone())
    }

    /// Download the resident params, masks and optimiser slots as raw
    /// vectors. Diagnostics/tests only (metered d2h traffic!) — the
    /// replica-parity suite uses it to prove lockstep across devices.
    #[allow(clippy::type_complexity)]
    pub fn dump_resident(
        &self,
    ) -> Result<(Vec<Vec<f32>>, Vec<Vec<f32>>, Vec<Vec<f32>>, Vec<Vec<f32>>)> {
        let dl = |bufs: &[xla::PjRtBuffer]| -> Result<Vec<Vec<f32>>> {
            bufs.iter()
                .map(|b| b.to_literal_sync()?.to_vec::<f32>())
                .collect()
        };
        Ok((
            dl(&self.params)?,
            dl(&self.masks_fwd)?,
            dl(&self.masks_bwd)?,
            dl(&self.opt)?,
        ))
    }

    /// Run an eval-convention artifact (eval or grad_norms) against the
    /// resident params + forward masks, streaming only the batch.
    /// Returns all outputs downloaded (they are scalars for eval,
    /// per-tensor |grad| maps for grad_norms — both refresh-cadence
    /// sized, not per-step).
    pub fn run_with_fwd_masks(
        &self,
        exe: &Executable,
        x: TensorRef<'_>,
        y: TensorRef<'_>,
    ) -> Result<Vec<HostTensor>> {
        let mut inputs: Vec<DeviceInput<'_>> =
            Vec::with_capacity(self.eval_layout.batch.end);
        for buf in &self.params {
            inputs.push(DeviceInput::Resident(buf));
        }
        for buf in &self.masks_fwd {
            inputs.push(DeviceInput::Resident(buf));
        }
        inputs.push(DeviceInput::Host(x));
        inputs.push(DeviceInput::Host(y));
        let outs = exe.run_device_on(&inputs, self.device)?;
        outs.iter()
            .zip(&exe.spec.outputs)
            .map(|(buf, io)| exe.download(buf, io))
            .collect()
    }
}

/// Analytic per-step traffic account for a model under the
/// device-resident protocol, split into what stays resident and what
/// streams — the successor of the old `step_upload_bytes` scalar
/// (which assumed every tensor re-uploaded every step).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct TrafficModel {
    /// Data-parallel replica count the account is for (1 = the plain
    /// single-device protocol).
    pub replicas: u64,
    /// Bytes parked on *each* device between refreshes (θ + opt +
    /// masks); the replica set holds `replicas ×` this in total.
    pub resident_bytes: u64,
    /// Host→device bytes per steady-state step, total across replicas
    /// (each replica streams its batch shard + its own step scalars).
    pub step_h2d_bytes: u64,
    /// Host→device bytes per steady-state step through *one* replica's
    /// link (its shard + the step scalars). Equals `step_h2d_bytes`
    /// when `replicas == 1`.
    pub replica_step_h2d_bytes: u64,
    /// Interconnect bytes per step for the fixed-order gradient
    /// all-reduce, summed over the replica set (0 when `replicas == 1`
    /// — a lone participant moves nothing).
    pub allreduce_step_bytes: u64,
    /// Device→host bytes per steady-state step (the loss scalar,
    /// downloaded from replica 0 only).
    pub step_d2h_bytes: u64,
    /// Device→host bytes at a mask refresh: the dense θ for host
    /// Top-K (slots stay resident), plus the grad_norms outputs for
    /// gradient-guided strategies. Replica 0 serves the sync, so this
    /// does not scale with the replica count.
    pub refresh_d2h_bytes: u64,
    /// Host→device bytes at a mask refresh (new masks — broadcast to
    /// every replica so the A/B sets never diverge; plus a grad_norms
    /// batch on replica 0 and/or a per-replica params re-upload for
    /// strategies that need them — SET/RigL).
    pub refresh_h2d_bytes: u64,
    /// Device→host bytes of a full sync (checkpoint capture / end of
    /// run): θ + optimiser slots.
    pub checkpoint_d2h_bytes: u64,
    /// What the pre-device-resident loop moved *every step*
    /// (θ + masks + opt up, θ + opt + loss down) — the baseline the
    /// bench trajectory measures against.
    pub legacy_step_bytes: u64,
}

impl TrafficModel {
    /// Build the account from a model's manifest entry.
    /// `strategy_rewrites_weights` adds the param re-upload that
    /// SET/RigL refreshes require; `strategy_uses_grad_norms` adds the
    /// grad_norms pass RigL runs at each update (one batch up, one
    /// dense |grad| tensor per sparse param down).
    pub fn of(
        model: &ModelEntry,
        strategy_rewrites_weights: bool,
        strategy_uses_grad_norms: bool,
    ) -> Result<Self> {
        Self::replicated(model, strategy_rewrites_weights, strategy_uses_grad_norms, 1)
    }

    /// The account for an N-replica data-parallel run (`replicas = 1`
    /// reduces exactly to [`TrafficModel::of`]). Per-replica steady
    /// state streams one batch shard + the step scalars up; the
    /// gradient payload (the replication grad artifact's outputs)
    /// crosses the interconnect once per replica per step; refresh
    /// broadcasts the masks to every replica while θ downloads and the
    /// grad_norms batch stay on replica 0.
    pub fn replicated(
        model: &ModelEntry,
        strategy_rewrites_weights: bool,
        strategy_uses_grad_norms: bool,
        replicas: usize,
    ) -> Result<Self> {
        let layout = model.train_layout()?;
        let p_bytes: u64 =
            model.params.iter().map(|p| 4 * p.shape.numel() as u64).sum();
        let m_bytes: u64 = model
            .sparse_params()
            .iter()
            .map(|p| 4 * p.shape.numel() as u64)
            .sum();
        let slots = model.optimizer.slots() as u64;
        let batch_bytes: u64 = model.train.inputs[layout.batch.clone()]
            .iter()
            .map(|io| 4 * io.shape.numel() as u64)
            .sum();
        let scalar_bytes = 4 * layout.scalars.len() as u64;
        let loss_bytes = 4u64;
        let grad_norms_h2d = if strategy_uses_grad_norms { batch_bytes } else { 0 };
        let grad_norms_d2h = if strategy_uses_grad_norms { m_bytes } else { 0 };
        let r = replicas.max(1) as u64;
        let (shard_bytes, allreduce_step_bytes) = if replicas > 1 {
            let rep = model.replication.as_ref().with_context(|| {
                format!(
                    "model {}: traffic account for {replicas} replicas needs \
                     replication artifacts (grad/apply)",
                    model.name
                )
            })?;
            if rep.replicas != replicas {
                bail!(
                    "model {}: replication artifacts were built for {} \
                     replicas, account requested for {replicas}",
                    model.name,
                    rep.replicas
                );
            }
            let shard: u64 = rep
                .grad
                .inputs
                .iter()
                .map(|io| 4 * io.shape.numel() as u64)
                .sum();
            let payload: u64 = rep
                .grad
                .outputs
                .iter()
                .map(|io| 4 * io.shape.numel() as u64)
                .sum();
            (shard, r * payload)
        } else {
            (batch_bytes, 0)
        };
        Ok(TrafficModel {
            replicas: r,
            resident_bytes: p_bytes * (1 + slots) + 2 * m_bytes,
            step_h2d_bytes: r * (shard_bytes + scalar_bytes),
            replica_step_h2d_bytes: shard_bytes + scalar_bytes,
            allreduce_step_bytes,
            step_d2h_bytes: loss_bytes,
            refresh_d2h_bytes: p_bytes + grad_norms_d2h,
            refresh_h2d_bytes: r * 2 * m_bytes
                + grad_norms_h2d
                + if strategy_rewrites_weights { r * p_bytes } else { 0 },
            checkpoint_d2h_bytes: p_bytes * (1 + slots),
            legacy_step_bytes: p_bytes * (1 + slots) + 2 * m_bytes
                + batch_bytes
                + scalar_bytes
                + p_bytes * (1 + slots)
                + loss_bytes,
        })
    }

    /// Mean bytes/step when refreshing every N steps.
    pub fn amortized_step_bytes(&self, refresh_every: usize) -> f64 {
        let n = refresh_every.max(1) as f64;
        (self.step_h2d_bytes + self.step_d2h_bytes) as f64
            + (self.refresh_d2h_bytes + self.refresh_h2d_bytes) as f64 / n
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::synthetic::Synthetic;
    use crate::runtime::Runtime;
    use crate::sparsity::ParamStore;

    #[test]
    fn traffic_model_decouples_steps_from_dense_size() {
        let synth = Synthetic::tiny();
        let t = TrafficModel::of(&synth.model, false, false).unwrap();
        // steady-state traffic is batch-sized, independent of θ
        let dense_bytes: u64 = synth
            .model
            .params
            .iter()
            .map(|p| 4 * p.shape.numel() as u64)
            .sum();
        assert!(t.resident_bytes >= dense_bytes);
        assert!(t.step_h2d_bytes < dense_bytes);
        assert_eq!(t.step_d2h_bytes, 4);
        assert!(t.legacy_step_bytes > t.step_h2d_bytes + t.step_d2h_bytes);
        // amortisation approaches the steady-state floor as N grows
        let floor = (t.step_h2d_bytes + t.step_d2h_bytes) as f64;
        assert!(t.amortized_step_bytes(1) > t.amortized_step_bytes(100));
        assert!(t.amortized_step_bytes(1_000_000) - floor < 1.0 + floor * 1e-3);
        // grad-norms strategies (RigL) pay one batch up + one dense
        // |grad| per sparse tensor down at each refresh
        let g = TrafficModel::of(&synth.model, true, true).unwrap();
        assert!(g.refresh_d2h_bytes > t.refresh_d2h_bytes);
        assert!(g.refresh_h2d_bytes > t.refresh_h2d_bytes);
        assert_eq!(g.step_h2d_bytes, t.step_h2d_bytes, "steady state unchanged");
        // refresh downloads θ only; a checkpoint additionally syncs
        // the optimiser slots
        assert!(t.checkpoint_d2h_bytes > t.refresh_d2h_bytes);
    }

    #[test]
    fn replicated_traffic_keys_accounting_by_replica() {
        let synth = Synthetic::tiny();
        let base = TrafficModel::of(&synth.model, false, false).unwrap();
        assert_eq!(base.replicas, 1);
        assert_eq!(base.replica_step_h2d_bytes, base.step_h2d_bytes);
        assert_eq!(base.allreduce_step_bytes, 0, "one replica: no interconnect");
        // without replication artifacts, an N-replica account is a
        // clear error, not a silently-wrong single-device number
        assert!(TrafficModel::replicated(&synth.model, false, false, 2).is_err());

        let replicated = synth.replicated(4).unwrap();
        let t = TrafficModel::replicated(&replicated.model, false, false, 4).unwrap();
        assert_eq!(t.replicas, 4);
        assert_eq!(t.step_h2d_bytes, 4 * t.replica_step_h2d_bytes);
        // each replica uploads its shard: shard + scalars < full batch + scalars
        assert!(t.replica_step_h2d_bytes < base.step_h2d_bytes);
        // payload = the grad outputs (two scalars), once per replica
        assert_eq!(t.allreduce_step_bytes, 4 * 2 * 4);
        // refresh: masks broadcast to all replicas, θ down from one
        assert_eq!(t.refresh_h2d_bytes, 4 * base.refresh_h2d_bytes);
        assert_eq!(t.refresh_d2h_bytes, base.refresh_d2h_bytes);
        assert_eq!(t.checkpoint_d2h_bytes, base.checkpoint_d2h_bytes);
        // mismatched replica count is rejected
        assert!(TrafficModel::replicated(&replicated.model, false, false, 2).is_err());
    }

    #[test]
    fn round_trip_through_device_state_preserves_host_state() {
        let synth = Synthetic::tiny();
        let mut rt = Runtime::new().unwrap();
        synth.install(&mut rt).unwrap();
        let store = ParamStore::init(&synth.model.params, 7);
        let slots = synth.model.optimizer.slots();
        let opt: Vec<Vec<f32>> = synth
            .model
            .params
            .iter()
            .flat_map(|p| {
                std::iter::repeat_with(move || vec![0.25f32; p.shape.numel()])
                    .take(slots)
            })
            .collect();
        let dev = DeviceState::from_host(
            rt.client().clone(),
            &synth.model,
            &store,
            &opt,
        )
        .unwrap();
        let mut store2 = ParamStore::init(&synth.model.params, 999);
        let mut opt2: Vec<Vec<f32>> =
            opt.iter().map(|s| vec![0.0; s.len()]).collect();
        dev.sync_to_host(&mut store2, &mut opt2).unwrap();
        for (a, b) in store.entries.iter().zip(&store2.entries) {
            assert_eq!(a.values, b.values);
        }
        assert_eq!(opt, opt2);
    }
}
