//! `StrictBackend`: the host-sim with real-PJRT buffer ownership
//! enforced at runtime.
//!
//! The raw simulator's `Arc`-backed buffers tolerate any access
//! pattern, so a runtime layer that silently reuses a donated buffer
//! would still pass every bit-parity suite against it — and then
//! crash (or corrupt memory) the day real PJRT bindings are swapped
//! in. This wrapper is the tripwire: each buffer carries a shared
//! donation flag; donating through *any* alias (an
//! [`ExecInput::Donate`] execution input, a consuming
//! [`BufferOps::tuple_parts`] / [`BufferOps::scatter_mask_update`])
//! flips the flag, and every later data access through any alias is a
//! hard `use-after-donate` error. Metadata reads
//! (`element_count`/`element_type`/`is_tuple`/`device`) stay legal —
//! PJRT keeps shape records host-side.
//!
//! Donation flags flip *before* the wrapped call runs, so a failed
//! execution leaves its donated inputs poisoned — exactly the
//! real-hardware contract (the donated memory is gone either way).
//!
//! Everything else — numerics, device layout, transfer metering — is
//! delegated untouched, so losses, params, masks, optimizer state and
//! `TransferSnapshot` counters are bitwise identical to the `sim`
//! backend. That identity is what lets the parity suites certify the
//! runtime layer under `TOPKAST_BACKEND=strict`.

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;

use anyhow::{bail, Result};

use crate::tensor::SparseSet;
use crate::xla;

use super::backend::{Backend, BufferOps, ExecInput};

/// Host-sim client plus donation bookkeeping. See the module docs.
#[derive(Clone)]
pub struct StrictBackend {
    inner: xla::PjRtClient,
}

/// A sim buffer plus a donation flag shared by every clone (clones
/// alias the same device memory, so donation kills them all).
#[derive(Clone)]
pub struct StrictBuffer {
    inner: xla::PjRtBuffer,
    donated: Arc<AtomicBool>,
}

pub struct StrictExecutable {
    inner: xla::PjRtLoadedExecutable,
}

impl StrictBuffer {
    fn fresh(inner: xla::PjRtBuffer) -> StrictBuffer {
        StrictBuffer {
            inner,
            donated: Arc::new(AtomicBool::new(false)),
        }
    }

    /// Bail if this buffer (through any alias) has been donated.
    fn guard(&self, op: &str) -> Result<()> {
        if self.donated.load(Ordering::SeqCst) {
            bail!(
                "use-after-donate: {op} on a buffer whose ownership was \
                 already transferred (donated to an execution or consumed \
                 by tuple_parts/scatter_mask_update)"
            );
        }
        Ok(())
    }

    /// Complete a donation: every alias of this buffer is dead now.
    fn mark_donated(&self) {
        self.donated.store(true, Ordering::SeqCst);
    }
}

impl StrictBackend {
    pub fn with_devices(devices: usize) -> Result<StrictBackend> {
        Ok(StrictBackend {
            inner: xla::PjRtClient::cpu_with_devices(devices)?,
        })
    }

    /// Wrap an already-configured sim client (kernel mode / thread
    /// budget set programmatically) in donation checking.
    pub fn from_client(inner: xla::PjRtClient) -> StrictBackend {
        StrictBackend { inner }
    }
}

impl BufferOps for StrictBuffer {
    fn element_count(&self) -> usize {
        self.inner.element_count()
    }

    fn element_type(&self) -> Option<xla::ElemType> {
        self.inner.element_type()
    }

    fn is_tuple(&self) -> bool {
        self.inner.is_tuple()
    }

    fn device(&self) -> usize {
        self.inner.device()
    }

    fn to_literal_sync(&self) -> Result<xla::Literal> {
        self.guard("to_literal_sync")?;
        self.inner.to_literal_sync()
    }

    fn gather_to_host(&self, indices: &[u32]) -> Result<Vec<f32>> {
        self.guard("gather_to_host")?;
        self.inner.gather_to_host(indices)
    }

    fn tuple_parts(self) -> Result<Vec<Self>> {
        self.guard("tuple_parts")?;
        self.mark_donated();
        Ok(self
            .inner
            .tuple_parts()?
            .into_iter()
            .map(StrictBuffer::fresh)
            .collect())
    }

    fn scatter_mask_update(self, added: &[u32], removed: &[u32]) -> Result<Self> {
        self.guard("scatter_mask_update")?;
        self.mark_donated();
        Ok(StrictBuffer::fresh(
            self.inner.scatter_mask_update(added, removed)?,
        ))
    }

    fn scatter_values_update(self, indices: &[u32], values: &[f32]) -> Result<Self> {
        self.guard("scatter_values_update")?;
        self.mark_donated();
        Ok(StrictBuffer::fresh(
            self.inner.scatter_values_update(indices, values)?,
        ))
    }

    fn debug_read_f32(&self) -> Option<Vec<f32>> {
        if self.donated.load(Ordering::SeqCst) {
            return None; // no free host view of dead memory
        }
        self.inner.debug_read_f32()
    }
}

impl Backend for StrictBackend {
    type Client = StrictBackend;
    type Buffer = StrictBuffer;
    type Executable = StrictExecutable;

    fn name(&self) -> &'static str {
        "strict"
    }

    fn platform_name(&self) -> String {
        self.inner.platform_name()
    }

    fn device_count(&self) -> usize {
        self.inner.device_count()
    }

    fn client(&self) -> Self::Client {
        self.clone()
    }

    fn buffer_from_host_buffer<T: xla::NativeType>(
        &self,
        data: &[T],
        dims: &[usize],
        device: Option<usize>,
    ) -> Result<Self::Buffer> {
        Ok(StrictBuffer::fresh(
            self.inner.buffer_from_host_buffer(data, dims, device)?,
        ))
    }

    fn mask_from_indices(
        &self,
        dims: &[usize],
        indices: &[u32],
        device: Option<usize>,
    ) -> Result<Self::Buffer> {
        Ok(StrictBuffer::fresh(
            self.inner.mask_from_indices(dims, indices, device)?,
        ))
    }

    fn compile(&self, comp: &xla::XlaComputation) -> Result<Self::Executable> {
        Ok(StrictExecutable {
            inner: self.inner.compile(comp)?,
        })
    }

    fn execute(
        &self,
        exe: &Self::Executable,
        inputs: Vec<ExecInput<'_, Self>>,
    ) -> Result<Vec<Self::Buffer>> {
        // guard every input before flipping any flag, so a buffer that
        // appears both as Donate and Borrow is caught, not poisoned
        for input in &inputs {
            input.buffer().guard("execute input")?;
        }
        // donation happens at dispatch: even a failed execution has
        // consumed the donated memory
        for input in &inputs {
            if let ExecInput::Donate(b) = input {
                b.mark_donated();
            }
        }
        let refs: Vec<&xla::PjRtBuffer> =
            inputs.iter().map(|i| &i.buffer().inner).collect();
        let result = exe.inner.execute_b(&refs)?;
        drop(refs);
        drop(inputs);
        let row = result.into_iter().next().unwrap_or_default();
        if row.is_empty() {
            bail!("executable returned no result");
        }
        Ok(row.into_iter().map(StrictBuffer::fresh).collect())
    }

    fn all_reduce_sum(&self, inputs: &[&Self::Buffer]) -> Result<Vec<Self::Buffer>> {
        for b in inputs {
            b.guard("all_reduce_sum input")?;
        }
        let refs: Vec<&xla::PjRtBuffer> = inputs.iter().map(|b| &b.inner).collect();
        // sim outputs may alias one Arc across devices; each replica
        // still gets its own donation flag — donating one replica's
        // reduced payload must not poison its siblings
        Ok(self
            .inner
            .all_reduce_sum(&refs)?
            .into_iter()
            .map(StrictBuffer::fresh)
            .collect())
    }

    fn all_reduce_sum_sparse(
        &self,
        inputs: &[&Self::Buffer],
        set: &SparseSet,
    ) -> Result<Vec<Self::Buffer>> {
        for b in inputs {
            b.guard("all_reduce_sum_sparse input")?;
        }
        let refs: Vec<&xla::PjRtBuffer> = inputs.iter().map(|b| &b.inner).collect();
        Ok(self
            .inner
            .all_reduce_sum_sparse(&refs, set)?
            .into_iter()
            .map(StrictBuffer::fresh)
            .collect())
    }

    fn transfer_stats(&self) -> xla::TransferSnapshot {
        self.inner.transfer_stats()
    }

    fn device_transfer_stats(&self, device: usize) -> Result<xla::TransferSnapshot> {
        self.inner.device_transfer_stats(device)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn upload(b: &StrictBackend, v: &[f32]) -> StrictBuffer {
        b.buffer_from_host_buffer(v, &[v.len()], None).unwrap()
    }

    #[test]
    fn clones_die_with_the_original_on_donation() {
        let backend = StrictBackend::with_devices(1).unwrap();
        let buf = upload(&backend, &[1.0, 2.0]);
        let alias = buf.clone();
        // donate through the original via a consuming op
        let _updated = buf.scatter_mask_update(&[0], &[]).unwrap();
        let err = alias.to_literal_sync().unwrap_err().to_string();
        assert!(err.contains("use-after-donate"), "{err}");
        let err = alias.gather_to_host(&[0]).unwrap_err().to_string();
        assert!(err.contains("use-after-donate"), "{err}");
        // metadata stays readable — host-side shape records
        assert_eq!(alias.element_count(), 2);
        assert!(!alias.is_tuple());
        assert_eq!(alias.debug_read_f32(), None);
    }

    #[test]
    fn borrowed_buffers_survive_execution() {
        let backend = StrictBackend::with_devices(1).unwrap();
        let buf = upload(&backend, &[3.0]);
        // all_reduce borrows: the input must stay readable
        let out = backend.all_reduce_sum(&[&buf]).unwrap();
        assert_eq!(out.len(), 1);
        assert!(buf.to_literal_sync().is_ok());
        // outputs carry fresh flags: donating one leaves inputs alive
        let _ = out.into_iter().next().unwrap().scatter_mask_update(&[], &[]).unwrap();
        assert!(buf.to_literal_sync().is_ok());
    }

    #[test]
    fn metering_delegates_exactly() {
        let backend = StrictBackend::with_devices(1).unwrap();
        let raw = xla::PjRtClient::cpu().unwrap();
        upload(&backend, &[1.0, 2.0, 3.0]);
        raw.buffer_from_host_buffer::<f32>(&[1.0, 2.0, 3.0], &[3], None)
            .unwrap();
        assert_eq!(backend.transfer_stats(), raw.transfer_stats());
    }
}
