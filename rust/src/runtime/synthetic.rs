//! Synthetic in-memory models: a complete `ModelEntry` (train / eval /
//! grad_norms) whose artifacts are built with the in-crate
//! `XlaBuilder` instead of the python AOT pipeline, plus a matching
//! deterministic `DataSource`.
//!
//! These exist so the full coordinator — device-resident loop, mask
//! refresh, checkpointing, async refresher — can be driven end-to-end
//! in environments without `artifacts/` (CI, the bench `step_traffic`
//! scenario, the parity suites). The compute graphs follow the exact
//! train/eval/grad_norms IO conventions of `python/compile/aot.py`
//! (see `ModelEntry::train_layout`): the update rule is a stand-in,
//! but it is deterministic, mask-respecting (no writes outside B, no
//! forward reads outside A's contribution), and exercises every input
//! group including the step scalars.

use std::collections::BTreeMap;
use std::path::PathBuf;

use anyhow::{bail, Result};

use super::client::Runtime;
use super::manifest::{
    ArtifactSpec, Dtype, InitKind, IoSpec, ModelEntry, Optimizer, ParamSpec,
    ReplicationSpec,
};
use crate::coordinator::{DataSource, Trainer, TrainerConfig};
use crate::sparsity::MaskStrategy;
use crate::tensor::{HostTensor, Shape};
use crate::util::rng::Pcg64;
use crate::xla;

/// A synthetic model: manifest entry + buildable computations.
#[derive(Clone)]
pub struct Synthetic {
    pub model: ModelEntry,
    features: usize,
    batch: usize,
}

impl Synthetic {
    /// Smallest preset (3 tensors, 2 sparse; SGD).
    pub fn tiny() -> Synthetic {
        Synthetic::new("syn_tiny", 8, 16, 4, Optimizer::Sgd)
    }

    /// A larger preset with two optimiser slots (Adam convention).
    pub fn small() -> Synthetic {
        Synthetic::new("syn_small", 64, 128, 16, Optimizer::Adam)
    }

    pub fn new(
        name: &str,
        features: usize,
        hidden: usize,
        batch: usize,
        optimizer: Optimizer,
    ) -> Synthetic {
        let out = 4usize;
        let params = vec![
            param("w1", &[features, hidden], InitKind::Normal, 0.5, true),
            param("b1", &[hidden], InitKind::Uniform, 0.2, false),
            param("w2", &[hidden, out], InitKind::Normal, 0.5, true),
        ];
        let slots = optimizer.slots();
        let np = params.len();

        let batch_io = vec![
            IoSpec {
                name: "x".into(),
                shape: Shape::new(&[batch, features]),
                dtype: Dtype::F32,
            },
            IoSpec { name: "y".into(), shape: Shape::new(&[batch]), dtype: Dtype::F32 },
        ];
        let scalar_io = |n: &str| IoSpec {
            name: n.into(),
            shape: Shape::new(&[1]),
            dtype: Dtype::F32,
        };
        let tensor_io = |prefix: &str, p: &ParamSpec| IoSpec {
            name: format!("{prefix}{}", p.name),
            shape: p.shape.clone(),
            dtype: Dtype::F32,
        };

        let mut train_inputs: Vec<IoSpec> =
            params.iter().map(|p| tensor_io("", p)).collect();
        for prefix in ["mf:", "mb:"] {
            train_inputs
                .extend(params.iter().filter(|p| p.sparse).map(|p| tensor_io(prefix, p)));
        }
        for p in &params {
            for j in 0..slots {
                train_inputs.push(tensor_io(&format!("opt{j}:"), p));
            }
        }
        train_inputs.extend(batch_io.iter().cloned());
        for s in ["lr", "step", "reg_scale", "inv_d"] {
            train_inputs.push(scalar_io(s));
        }
        let mut train_outputs: Vec<IoSpec> =
            params.iter().map(|p| tensor_io("new:", p)).collect();
        for p in &params {
            for j in 0..slots {
                train_outputs.push(tensor_io(&format!("newopt{j}:"), p));
            }
        }
        train_outputs.push(scalar_io("loss"));

        let mut eval_inputs: Vec<IoSpec> =
            params.iter().map(|p| tensor_io("", p)).collect();
        eval_inputs
            .extend(params.iter().filter(|p| p.sparse).map(|p| tensor_io("mf:", p)));
        eval_inputs.extend(batch_io.iter().cloned());
        let eval_outputs = vec![scalar_io("loss"), scalar_io("metric")];
        let gn_outputs: Vec<IoSpec> = params
            .iter()
            .filter(|p| p.sparse)
            .map(|p| tensor_io("g:", p))
            .collect();

        let mut config = BTreeMap::new();
        config.insert(
            "batch_size".to_string(),
            crate::util::json::Json::num(batch as f64),
        );
        let art = |suffix: &str, inputs: &[IoSpec], outputs: &[IoSpec]| ArtifactSpec {
            file: PathBuf::from(format!("<synthetic:{name}:{suffix}>")),
            inputs: inputs.to_vec(),
            outputs: outputs.to_vec(),
        };
        let model = ModelEntry {
            name: name.to_string(),
            kind: "synthetic".to_string(),
            optimizer,
            train: art("train", &train_inputs, &train_outputs),
            eval: art("eval", &eval_inputs, &eval_outputs),
            grad_norms: art("grad_norms", &eval_inputs, &gn_outputs),
            replication: None,
            params,
            config,
        };
        debug_assert_eq!(model.train.inputs.len(), np + 2 * 2 + np * slots + 6);
        Synthetic { model, features, batch }
    }

    /// Attach data-parallel replication artifacts for a concrete
    /// replica count: one shard-sized grad artifact per tree-aligned
    /// shard (TrainPrefix convention — θ | m_fwd | m_bwd | batch shard
    /// in; moment partial sums plus per-sparse-param bwd-masked
    /// row-affine gradients out) and an apply artifact that reproduces
    /// the fused train update bit-for-bit from the all-reduced payload.
    /// Fails when the batch has fewer examples than replicas.
    pub fn replicated(&self, replicas: usize) -> Result<Synthetic> {
        if replicas == 0 {
            bail!("replicas must be >= 1");
        }
        if self.batch < replicas {
            bail!(
                "model {}: batch of {} examples cannot feed {replicas} \
                 replicas (need at least one example per shard)",
                self.model.name,
                self.batch
            );
        }
        let name = &self.model.name;
        let layout = self.model.train_layout()?;
        let np = self.model.params.len();
        let ns = self.model.sparse_params().len();
        // payload: moment scalars, then one bwd-masked `g:<param>`
        // tensor per sparse param — the `g:` names are what routes
        // those slots through the sparse exchange (see
        // `runtime::replicated`)
        let mut payload = vec![
            IoSpec { name: "gsum_x".into(), shape: Shape::new(&[1]), dtype: Dtype::F32 },
            IoSpec { name: "gsum_y".into(), shape: Shape::new(&[1]), dtype: Dtype::F32 },
        ];
        payload.extend(self.model.params.iter().filter(|p| p.sparse).map(|p| {
            IoSpec {
                name: format!("g:{}", p.name),
                shape: p.shape.clone(),
                dtype: Dtype::F32,
            }
        }));
        let prefix = &self.model.train.inputs[..np + 2 * ns];
        let grads = super::replicated::shard_ranges(self.batch, replicas)
            .iter()
            .map(|r| {
                let len = r.len();
                let mut inputs = prefix.to_vec();
                inputs.push(IoSpec {
                    name: "x".into(),
                    shape: Shape::new(&[len, self.features]),
                    dtype: Dtype::F32,
                });
                inputs.push(IoSpec {
                    name: "y".into(),
                    shape: Shape::new(&[len]),
                    dtype: Dtype::F32,
                });
                ArtifactSpec {
                    // keyed by shard *length* only: equal-length shards
                    // share one compiled executable
                    file: PathBuf::from(format!(
                        "<synthetic:{name}:grad/r{replicas}/len{len}>"
                    )),
                    inputs,
                    outputs: payload.clone(),
                }
            })
            .collect();
        // apply: train-convention inputs with the two batch slots
        // widened into the 2 + ns payload slots (the trailing scalars
        // shift by ns; DeviceState::apply_step derives the payload
        // arity from exactly this widening)
        let mut apply_inputs = self.model.train.inputs.clone();
        apply_inputs.splice(layout.batch.clone(), payload);
        let apply = ArtifactSpec {
            file: PathBuf::from(format!("<synthetic:{name}:apply>")),
            inputs: apply_inputs,
            outputs: self.model.train.outputs.clone(),
        };
        let mut out = self.clone();
        out.model.replication = Some(ReplicationSpec { replicas, grads, apply });
        Ok(out)
    }

    /// Compile the computations and seed them into a runtime's
    /// executable cache, so `Runtime::load` (and therefore a stock
    /// `Trainer`) resolves them without touching disk. Includes the
    /// grad/apply pair when replication artifacts are attached.
    pub fn install<B: super::backend::Backend>(&self, rt: &mut Runtime<B>) -> Result<()> {
        let train = rt.compile_computation(&self.build_train()?, &self.model.train)?;
        rt.preload(train);
        let eval = rt.compile_computation(&self.build_eval(false)?, &self.model.eval)?;
        rt.preload(eval);
        let gn =
            rt.compile_computation(&self.build_eval(true)?, &self.model.grad_norms)?;
        rt.preload(gn);
        if let Some(rep) = &self.model.replication {
            // equal-length shards share a file key — compile each
            // distinct key once
            let mut seen = std::collections::BTreeSet::new();
            for grad in &rep.grads {
                if seen.insert(&grad.file) {
                    let exe = rt.compile_computation(&self.build_grad(grad)?, grad)?;
                    rt.preload(exe);
                }
            }
            let apply = rt.compile_computation(
                &self.build_step(&rep.apply, true)?,
                &rep.apply,
            )?;
            rt.preload(apply);
        }
        Ok(())
    }

    /// A fully-wired trainer over this model (own runtime + data). The
    /// runtime's simulated device set matches `cfg.replicas`, and
    /// replication artifacts are attached automatically when the config
    /// asks for more than one replica.
    pub fn trainer(
        &self,
        strategy: Box<dyn MaskStrategy>,
        cfg: TrainerConfig,
    ) -> Result<Trainer> {
        let rt = Runtime::with_devices(cfg.replicas.max(1))?;
        self.trainer_on(rt, strategy, cfg)
    }

    /// Like [`Self::trainer`], but over an explicitly-constructed
    /// runtime — tests use this to pin backend, kernel mode, and thread
    /// count programmatically instead of via the environment. The
    /// runtime's device set must already match `cfg.replicas`.
    pub fn trainer_on<B: super::backend::Backend>(
        &self,
        mut rt: Runtime<B>,
        strategy: Box<dyn MaskStrategy>,
        cfg: TrainerConfig,
    ) -> Result<Trainer<B>> {
        let replicas = cfg.replicas.max(1);
        let synth = if replicas > 1 && self.model.replication.is_none() {
            self.replicated(replicas)?
        } else {
            self.clone()
        };
        synth.install(&mut rt)?;
        let data = synth.data(cfg.seed ^ 0xDA7A);
        Trainer::new(rt, synth.model.clone(), strategy, data, cfg)
    }

    /// Deterministic data stream matching the model's batch shapes.
    pub fn data(&self, seed: u64) -> Box<dyn DataSource> {
        Box::new(SyntheticData {
            rng: Pcg64::new(seed, 0x5D47A),
            eval_seed: seed ^ 0xE7A1,
            batch: self.batch,
            features: self.features,
        })
    }

    fn build_train(&self) -> Result<xla::XlaComputation> {
        self.build_step(&self.model.train, false)
    }

    /// Per-shard partial-gradient computation (TrainPrefix convention:
    /// θ | m_fwd | m_bwd | batch shard in). The payload is the moment
    /// partial sums plus, per sparse param, the bwd-masked row-affine
    /// partial gradient — built on the same canonical row trees with
    /// the same *full-batch* constants as `build_step`, so the
    /// fixed-order all-reduce of tree-aligned shard partials is
    /// bit-identical to the fused in-graph reductions (see
    /// `runtime::replicated`). The `select(m_bwd)` leaves exact +0.0
    /// off the bwd set — the sparse exchange's payload contract.
    fn build_grad(&self, spec: &ArtifactSpec) -> Result<xla::XlaComputation> {
        let model = &self.model;
        let b = xla::XlaBuilder::new(&format!("{}_grad", model.name));
        let inputs = declare_params(&b, spec)?;
        let np = model.params.len();
        let ns = model.sparse_params().len();
        let x = &inputs[np + 2 * ns];
        let y = &inputs[np + 2 * ns + 1];
        let rows = spec.inputs[np + 2 * ns + 1].shape.numel();
        let rs = x.row_sum(rows)?;
        let mut outs = vec![rs.reduce_sum()?, y.reduce_sum()?];
        let u = (&rs / &b.constant_f32((self.batch * self.features) as f32)?)?;
        let mut mpos = 0usize;
        for (i, p) in model.params.iter().enumerate() {
            if !p.sparse {
                continue;
            }
            let theta = &inputs[i];
            let bwd = &inputs[np + ns + mpos];
            let g = affine_grad(&b, theta, &u, y, i, self.batch, rows)?;
            outs.push(g.select(bwd)?);
            mpos += 1;
        }
        b.tuple(&outs)?.build()
    }

    /// The shared update graph. With `from_payload = false` this is the
    /// fused train step (batch in, moments and row-affine gradients
    /// reduced in-graph on the canonical row trees); with `true` it is
    /// the replicated apply step, whose widened batch slots carry the
    /// all-reduced payload and whose moment division uses the
    /// *full-batch* element counts — every node downstream of the
    /// payload values is identical, which is what makes replicated runs
    /// bit-identical to single-device runs.
    fn build_step(
        &self,
        spec: &ArtifactSpec,
        from_payload: bool,
    ) -> Result<xla::XlaComputation> {
        let model = &self.model;
        let layout = model.train_layout()?;
        let slots = model.optimizer.slots();
        let ns = model.sparse_params().len();
        let suffix = if from_payload { "apply" } else { "train" };
        let b = xla::XlaBuilder::new(&format!("{}_{suffix}", model.name));
        let inputs = declare_params(&b, spec)?;

        let nx = b.constant_f32((self.batch * self.features) as f32)?;
        let ny = b.constant_f32(self.batch as f32)?;
        // the apply artifact widens the 2 batch slots into 2 + ns
        // payload slots, shifting the trailing scalars by ns
        let pshift = if from_payload { ns } else { 0 };
        // fused-only row machinery: the row sums feed both the scalar
        // moments and the per-param row-affine gradients, on exactly
        // the canonical trees the per-shard grad artifacts tile
        let fused_u = if from_payload {
            None
        } else {
            let rs = inputs[layout.batch.start].row_sum(self.batch)?;
            let u = (&rs / &nx)?;
            Some((rs, u))
        };
        let (xm, ym) = match &fused_u {
            Some((rs, _)) => (
                (&rs.reduce_sum()? / &nx)?,
                (&inputs[layout.batch.start + 1].reduce_sum()? / &ny)?,
            ),
            None => (
                (&inputs[layout.batch.start] / &nx)?,
                (&inputs[layout.batch.start + 1] / &ny)?,
            ),
        };
        let lr = &inputs[layout.scalars.start + pshift];
        let step = &inputs[layout.scalars.start + pshift + 1];
        let reg = &inputs[layout.scalars.start + pshift + 2];
        let inv_d = &inputs[layout.scalars.start + pshift + 3];
        // a bounded step-dependent wobble so the step scalar matters:
        // step_gain = 1 + 1e-3·step (kept tiny to stay finite)
        let step_gain =
            (b.constant_f32(1.0)? + (step * &b.constant_f32(1e-3)?)?)?;

        // mask slot per sparse param, in spec order
        let mut mask_of: BTreeMap<usize, usize> = BTreeMap::new();
        for (pos, (i, _)) in
            model.params.iter().enumerate().filter(|(_, p)| p.sparse).enumerate()
        {
            mask_of.insert(i, pos);
        }

        let mut new_params = Vec::with_capacity(model.params.len());
        let mut new_opt = Vec::with_capacity(model.params.len() * slots);
        let mut loss = b.constant_f32(0.01)?;
        // gather-matmul forward chain over the sparse params, seeded by
        // the batch moment broadcast over a single row — the O(nnz)
        // forward pass (the per-param `g` below is the fake gradient's
        // elementwise signal; it stays lazy under select/scatter_add)
        let mut cur = xm.clone();
        for (i, p) in model.params.iter().enumerate() {
            let theta = &inputs[layout.params.start + i];
            if let Some(&mpos) = mask_of.get(&i) {
                let fwd = &inputs[layout.masks_fwd.start + mpos];
                let bwd = &inputs[layout.masks_bwd.start + mpos];
                let dims = p.shape.dims();
                cur = b.masked_matmul(&cur, theta, fwd, 1, dims[0], dims[1])?;
                // forward contribution reads only A; updates only B
                let act = ((theta * fwd)? * &(inv_d * &b.constant_f32(0.05)?)?)?;
                // the reduced row-affine gradient: rebuilt in-graph for
                // the fused step, read straight from the payload slot
                // for apply — bit-identical by tree alignment
                let gi = match &fused_u {
                    Some((_, u)) => affine_grad(
                        &b,
                        theta,
                        u,
                        &inputs[layout.batch.start + 1],
                        i,
                        self.batch,
                        self.batch,
                    )?,
                    None => inputs[layout.batch.start + 2 + mpos].clone(),
                };
                let g = ((&gi * &step_gain)? + &act)?.select(bwd)?;
                let g2 = (g.clone() * g.clone())?;
                // slot 0: momentum-style accumulator; slot 1 (when
                // present): second-moment-style — both written only on B
                let s0 = &inputs[layout.opt.start + i * slots];
                let s0n = s0.scatter_add(
                    bwd,
                    &(&g + &(s0 * &b.constant_f32(-0.1)?)?)?,
                )?;
                let mut upd = s0n.clone();
                let mut slot_outs = vec![s0n];
                if slots == 2 {
                    let s1 = &inputs[layout.opt.start + i * slots + 1];
                    let s1n = s1.scatter_add(
                        bwd,
                        &(&g2 + &(s1 * &b.constant_f32(-0.05)?)?)?,
                    )?;
                    upd = (&upd + &(&s1n * &b.constant_f32(0.1)?)?)?;
                    slot_outs.push(s1n);
                }
                // §2.2: coordinates outside B stay bit-identical — the
                // scatter copies θ's bytes verbatim off the mask
                let delta = ((lr * &upd)? + (reg * theta)?)?;
                new_params.push(theta.scatter_add(
                    bwd,
                    &(&delta * &b.constant_f32(-1.0)?)?,
                )?);
                new_opt.extend(slot_outs);
                loss = (&loss + &g2.mean()?)?;
            } else {
                // dense params keep the fused scalar-moment update (no
                // payload slot: xm/ym reconstruct it exactly)
                let ci = b.constant_f32(0.013 * (i + 1) as f32)?;
                let g = (&((theta * &xm)? + (&ci * &ym)?)? * &step_gain)?;
                let s0 = &inputs[layout.opt.start + i * slots];
                let s0n = ((s0 * &b.constant_f32(0.9)?)? + g.clone())?;
                let mut upd = s0n.clone();
                let mut slot_outs = vec![s0n];
                if slots == 2 {
                    let s1 = &inputs[layout.opt.start + i * slots + 1];
                    let s1n = ((s1 * &b.constant_f32(0.95)?)? + (&g * &g)?)?;
                    upd = (&upd + &(&s1n * &b.constant_f32(0.1)?)?)?;
                    slot_outs.push(s1n);
                }
                let delta = ((lr * &upd)? + (reg * theta)?)?;
                new_params.push((theta - &delta)?);
                new_opt.extend(slot_outs);
                loss = (&loss + &(&g * &g)?.mean()?)?;
            }
        }
        // the chain's output row ties the loss to the forward matmuls
        loss = (&loss + &(cur.clone() * cur.clone())?.mean()?)?;

        let mut outs = new_params;
        outs.extend(new_opt);
        outs.push(loss);
        b.tuple(&outs)?.build()
    }

    /// Eval (`grad_norms = false`) or grad-norms (`true`) computation —
    /// both read params + forward masks + one batch.
    fn build_eval(&self, grad_norms: bool) -> Result<xla::XlaComputation> {
        let model = &self.model;
        let spec = if grad_norms { &model.grad_norms } else { &model.eval };
        let layout = model.eval_layout(spec)?;
        let b = xla::XlaBuilder::new(&format!(
            "{}_{}",
            model.name,
            if grad_norms { "grad_norms" } else { "eval" }
        ));
        let inputs = declare_params(&b, spec)?;
        let xm = inputs[layout.batch.start].mean()?;
        let ym = inputs[layout.batch.start + 1].mean()?;

        let mut mask_pos = 0usize;
        let mut loss = b.constant_f32(0.01)?;
        let mut gn_outs = Vec::new();
        // batched gather-matmul chain x → every masked layer (eval
        // only; the grad-norms graph keeps its dense proxy outputs)
        let mut cur = if grad_norms {
            None
        } else {
            Some(inputs[layout.batch.start].clone())
        };
        for (i, p) in model.params.iter().enumerate() {
            let theta = &inputs[layout.params.start + i];
            let active = if p.sparse {
                let fwd = &inputs[layout.masks_fwd.start + mask_pos];
                mask_pos += 1;
                if grad_norms {
                    // dense |grad| proxy: positive everywhere, so the
                    // RigL grow criterion sees off-mask mass
                    gn_outs.push(((theta * theta)? + (&xm * &xm)?)?);
                }
                if let Some(c) = cur.take() {
                    let dims = p.shape.dims();
                    cur = Some(b.masked_matmul(
                        &c,
                        theta,
                        fwd,
                        self.batch,
                        dims[0],
                        dims[1],
                    )?);
                }
                theta.select(fwd)?
            } else {
                theta.clone()
            };
            loss = (&loss + &(&active * &active)?.mean()?)?;
        }
        loss = (&loss + &(&xm * &xm)?)?;
        if let Some(z) = &cur {
            loss = (&loss + &(z.clone() * z.clone())?.mean()?)?;
        }
        let metric = ym;
        if grad_norms {
            b.tuple(&gn_outs)?.build()
        } else {
            b.tuple(&[loss, metric])?.build()
        }
    }
}

fn param(
    name: &str,
    dims: &[usize],
    init: InitKind,
    init_scale: f32,
    sparse: bool,
) -> ParamSpec {
    ParamSpec {
        name: name.into(),
        shape: Shape::new(dims),
        init,
        init_scale,
        sparse,
        mac: dims.iter().product::<usize>() as u64,
    }
}

/// The row-affine gradient for sparse param `i` over `rows` examples:
/// `Σ_e (u_e·θ + w_e)` with `w_e = y_e·(c_i / batch)`, evaluated on the
/// canonical row tree (`row_affine_sum`). `u` must be the row sums of x
/// divided by the *full-batch* element count — the fused train graph
/// (full batch) and every per-shard grad graph build exactly this op
/// sequence with exactly these constants, which is what makes their
/// trees compose bitwise under the fixed-order all-reduce.
fn affine_grad(
    b: &xla::XlaBuilder,
    theta: &xla::XlaOp,
    u: &xla::XlaOp,
    y: &xla::XlaOp,
    i: usize,
    batch: usize,
    rows: usize,
) -> Result<xla::XlaOp> {
    let ci = 0.013 * (i + 1) as f32;
    let w = (y * &b.constant_f32(ci / batch as f32)?)?;
    b.row_affine_sum(u, &w, theta, rows)
}

/// Declare one builder parameter per artifact input, in order.
fn declare_params(b: &xla::XlaBuilder, spec: &ArtifactSpec) -> Result<Vec<xla::XlaOp>> {
    spec.inputs
        .iter()
        .enumerate()
        .map(|(i, io)| {
            b.parameter_s(
                i as i64,
                &xla::Shape::array::<f32>(io.shape.dims().to_vec()),
                &io.name,
            )
        })
        .collect()
}

/// Deterministic batches matching the synthetic model's shapes.
struct SyntheticData {
    rng: Pcg64,
    eval_seed: u64,
    batch: usize,
    features: usize,
}

fn gen_batch(
    rng: &mut Pcg64,
    batch: usize,
    features: usize,
) -> (HostTensor, HostTensor) {
    let x: Vec<f32> = (0..batch * features).map(|_| rng.normal_f32(1.0)).collect();
    let y: Vec<f32> = (0..batch).map(|_| rng.normal_f32(1.0)).collect();
    (
        HostTensor {
            shape: Shape::new(&[batch, features]),
            data: crate::tensor::TensorData::F32(x),
        },
        HostTensor {
            shape: Shape::new(&[batch]),
            data: crate::tensor::TensorData::F32(y),
        },
    )
}

impl DataSource for SyntheticData {
    fn next_train(&mut self) -> (HostTensor, HostTensor) {
        gen_batch(&mut self.rng, self.batch, self.features)
    }

    fn eval_batch(&mut self, idx: usize) -> Option<(HostTensor, HostTensor)> {
        if idx >= 4 {
            return None;
        }
        let mut rng = Pcg64::new(self.eval_seed, idx as u64 + 1);
        Some(gen_batch(&mut rng, self.batch, self.features))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::client::TensorRef;

    #[test]
    fn artifacts_compile_and_match_layouts() {
        for synth in [Synthetic::tiny(), Synthetic::small()] {
            let mut rt = Runtime::new().unwrap();
            synth.install(&mut rt).unwrap();
            assert!(synth.model.train_layout().is_ok());
            assert!(synth.model.eval_layout(&synth.model.eval).is_ok());
            // load resolves from the preloaded cache
            let exe = rt.load(&synth.model.train).unwrap();
            assert_eq!(exe.spec.inputs.len(), synth.model.train.inputs.len());
        }
    }

    #[test]
    fn train_step_respects_backward_mask() {
        let synth = Synthetic::tiny();
        let mut rt = Runtime::new().unwrap();
        synth.install(&mut rt).unwrap();
        let model = &synth.model;
        let layout = model.train_layout().unwrap();
        let mut store = crate::sparsity::ParamStore::init(&model.params, 3);
        // sparse masks: fwd = bwd = top half by magnitude
        for e in store.entries.iter_mut() {
            if let Some(m) = e.masks.as_mut() {
                let n = e.values.len();
                let mask = crate::sparsity::topk::topk_mask(&e.values, n / 2);
                m.set_fwd(mask.clone());
                m.set_bwd(mask);
            }
        }
        let slots = model.optimizer.slots();
        let opt: Vec<Vec<f32>> = model
            .params
            .iter()
            .flat_map(|p| {
                std::iter::repeat_with(move || vec![0.0f32; p.shape.numel()])
                    .take(slots)
            })
            .collect();
        let mut data = synth.data(1);
        let (x, y) = data.next_train();
        let dense_masks: Vec<(Vec<f32>, Vec<f32>)> = store
            .entries
            .iter()
            .filter_map(|e| e.masks.as_ref().map(|m| (m.fwd_dense(), m.bwd_dense())))
            .collect();
        let mut inputs: Vec<TensorRef<'_>> = vec![];
        for e in &store.entries {
            inputs.push(TensorRef::F32(&e.values));
        }
        for fwd in [true, false] {
            for m in &dense_masks {
                inputs.push(TensorRef::F32(if fwd { &m.0 } else { &m.1 }));
            }
        }
        for slot in &opt {
            inputs.push(TensorRef::F32(slot));
        }
        inputs.push(TensorRef::from(&x));
        inputs.push(TensorRef::from(&y));
        let scalars = [[0.05f32], [1.0], [1e-4], [5.0]];
        for s in &scalars {
            inputs.push(TensorRef::F32(&s[..]));
        }
        let exe = rt.load(&model.train).unwrap();
        let outs = exe.run_borrowed(&inputs).unwrap();
        assert_eq!(outs.len(), model.params.len() * (1 + slots) + 1);
        let loss = outs[layout.out_loss].as_f32().unwrap()[0];
        assert!(loss.is_finite() && loss > 0.0, "loss {loss}");
        // no updates outside B; some inside
        for (i, p) in model.params.iter().enumerate() {
            if !p.sparse {
                continue;
            }
            let before = &store.get(&p.name).unwrap().values;
            let masks = store.get(&p.name).unwrap().masks.as_ref().unwrap();
            let after = outs[i].as_f32().unwrap();
            let mut inside = 0;
            for j in 0..before.len() {
                if before[j] != after[j] {
                    assert!(masks.bwd().contains(j as u32), "{}: leak at {j}", p.name);
                    inside += 1;
                }
            }
            assert!(inside > 0, "{}: no updates inside B", p.name);
        }
    }

    #[test]
    fn replication_artifacts_compile_and_follow_the_train_layout() {
        for replicas in [2usize, 3, 4] {
            let synth = Synthetic::tiny().replicated(replicas).unwrap();
            let mut rt = Runtime::with_devices(replicas).unwrap();
            synth.install(&mut rt).unwrap();
            let rep = synth.model.replication.as_ref().unwrap();
            assert_eq!(rep.replicas, replicas);
            assert_eq!(rep.grads.len(), replicas);
            // apply follows the train convention with the two batch
            // slots widened into the 2 + ns payload slots
            let ns = synth.model.sparse_params().len();
            assert_eq!(
                rep.apply.inputs.len(),
                synth.model.train.inputs.len() + ns
            );
            assert_eq!(rep.apply.outputs.len(), synth.model.train.outputs.len());
            // the per-shard grad artifacts tile the batch tree-aligned
            let layout = synth.model.train_layout().unwrap();
            let full_x = synth.model.train.inputs[layout.batch.start].shape.numel();
            let shard_x: usize = rep
                .grads
                .iter()
                .map(|g| g.inputs[g.inputs.len() - 2].shape.numel())
                .sum();
            assert_eq!(shard_x, full_x, "shards tile the batch");
            for grad in &rep.grads {
                assert_eq!(grad.outputs.len(), 2 + ns);
                assert!(grad.outputs[2].name.starts_with("g:"));
                assert!(rt.get(grad).is_ok(), "grad preloaded");
            }
            assert!(rt.get(&rep.apply).is_ok(), "apply preloaded");
        }
        // batch 4 shards down to 3 (unequal, tree-aligned), but a shard
        // cannot be smaller than one example
        assert!(Synthetic::tiny().replicated(5).is_err(), "4 examples < 5");
        assert!(Synthetic::tiny().replicated(0).is_err());
    }

    #[test]
    fn data_stream_is_deterministic() {
        let synth = Synthetic::tiny();
        let mut a = synth.data(9);
        let mut b = synth.data(9);
        assert_eq!(a.next_train(), b.next_train());
        assert_eq!(a.next_train(), b.next_train());
        assert_eq!(a.eval_batch(0), b.eval_batch(0));
        assert!(a.eval_batch(99).is_none());
        let mut c = synth.data(10);
        assert_ne!(a.eval_batch(1), c.eval_batch(1));
    }
}
