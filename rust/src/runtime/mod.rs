//! Runtime: the xla (PJRT) wrapper that loads the AOT HLO artifacts
//! and executes them from the coordinator's hot path, plus the
//! device-resident training state that keeps θ/opt/masks on the
//! accelerator between host syncs.
//!
//! Flow (see /opt/xla-example/load_hlo): HLO *text* →
//! `HloModuleProto::from_text_file` → `XlaComputation::from_proto` →
//! `PjRtClient::compile` → buffer-in/buffer-out execution. Text is the
//! interchange format because jax ≥ 0.5 emits 64-bit instruction ids
//! that xla_extension 0.5.1 rejects in proto form.
//!
//! See `backend` for the trait seam (and its buffer-ownership
//! contract) everything above executes through, `device_state` for the
//! resident-state protocol and its sync points, `replicated` for the
//! data-parallel replica protocol on top of it, and `synthetic` for
//! artifact-free in-memory models.

pub mod backend;
pub mod client;
pub mod device_state;
pub mod fault;
pub mod infer_state;
pub mod manifest;
#[cfg(feature = "pjrt")]
pub mod pjrt;
pub mod replicated;
pub mod strict;
pub mod synthetic;

pub use backend::{env_backend_name, AnyBackend, Backend, BufferOps, ExecInput, BACKEND_ENV};
pub use fault::{FaultBackend, FaultPlan, RuntimeError, FAULTS_ENV};
pub use client::{DeviceInput, Executable, Runtime, TensorRef};
pub use device_state::{DeviceState, TrafficModel};
pub use infer_state::InferState;
pub use manifest::{
    ArtifactSpec, Dtype, EvalLayout, InitKind, IoSpec, Manifest, ModelEntry,
    Optimizer, ParamSpec, ReplicatedLayout, ReplicationSpec, TrainLayout,
};
pub use replicated::{shard_ranges, ReplicatedState};
pub use strict::StrictBackend;
pub use synthetic::Synthetic;
