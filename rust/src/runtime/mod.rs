//! Runtime: the xla crate (PJRT C API) wrapper that loads the AOT HLO
//! artifacts and executes them from the coordinator's hot path.
//!
//! Flow (see /opt/xla-example/load_hlo): HLO *text* →
//! `HloModuleProto::from_text_file` → `XlaComputation::from_proto` →
//! `PjRtClient::compile` → `execute`. Text is the interchange format
//! because jax ≥ 0.5 emits 64-bit instruction ids that xla_extension
//! 0.5.1 rejects in proto form.

pub mod client;
pub mod manifest;

pub use client::{Executable, Runtime};
pub use manifest::{
    ArtifactSpec, Dtype, InitKind, IoSpec, Manifest, ModelEntry, Optimizer,
    ParamSpec,
};
