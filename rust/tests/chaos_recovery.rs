//! Chaos acceptance suite: deterministic fault injection end-to-end.
//!
//! Proves the load-bearing properties of the fault-hardened runtime
//! (`runtime::fault`, the trainer's journal recovery, replicated
//! re-sharding, and serve degradation):
//!
//! 1. **Chaos parity** — a training run that absorbs injected
//!    transient transfer/exec faults recovers to a state **bitwise
//!    identical** to the run that never faulted: every per-step loss,
//!    every parameter, every mask, every optimiser slot.
//! 2. **Device loss & elastic join** — a replicated run that
//!    permanently loses a device mid-run quarantines it, re-shards to
//!    the survivors (who keep exchanging bwd-masked gradients over the
//!    sparse all-reduce), and still matches the clean run bit-for-bit,
//!    with the replica lockstep invariant intact. A revived device
//!    re-admitted with `join_replica` receives θ + opt dense plus the
//!    installed masks as index lists — 4·Σ(|fwd|+|bwd|) bytes, metered
//!    exactly — and the rejoined run continues bitwise.
//! 3. **Serve degradation** — a server under exec faults answers every
//!    non-shed request with logits bitwise identical to a fault-free
//!    server; the bounded queue sheds with the explicit [`Shed`] error
//!    and deadlines expire stale requests; a mid-swap device loss
//!    aborts the swap and leaves the **old** checkpoint serving.
//!
//! All schedules are seeded ([`FaultPlan`]), so every scenario here is
//! deterministic. Where a property depends on *some* fault actually
//! firing (probabilistic plans) the test probes plan seeds until one
//! fires — each probed run still has to hold the parity invariant, so
//! the probing only ever adds coverage. The inner backend comes from
//! `TOPKAST_BACKEND` (the CI sim/strict matrix); `TOPKAST_FAULTS`, when
//! set, is exercised as an extra transient plan in the parity test (the
//! CI fault-seed axis).

use topkast::coordinator::{DataSource as _, Trainer, TrainerConfig};
use topkast::runtime::{AnyBackend, FaultPlan, Runtime, RuntimeError, Synthetic};
use topkast::serve::{CheckpointSwapper, Completion, ModelServer, ServeConfig, Shed};
use topkast::sparsity::TopKast;

fn cfg(steps: usize, refresh_every: usize, seed: u64, replicas: usize) -> TrainerConfig {
    TrainerConfig { steps, refresh_every, seed, replicas, ..TrainerConfig::default() }
}

fn strategy() -> Box<TopKast> {
    Box::new(TopKast::from_sparsities(0.8, 0.5))
}

/// A trainer over the env-selected backend wrapped in a
/// [`FaultBackend`] with the given plan — the construction
/// `Session::build` performs for a spec with `faults` set.
///
/// Construction itself uploads the initial resident state, so a plan
/// with transfer faults (or an early `lose` threshold) can fault the
/// build; that error is returned for the caller to classify.
fn faulty_trainer(
    synth: &Synthetic,
    cfg: TrainerConfig,
    plan: FaultPlan,
) -> anyhow::Result<Trainer> {
    let replicas = cfg.replicas.max(1);
    let inner = AnyBackend::from_env(replicas)?;
    let client = AnyBackend::faulty(inner, plan);
    let mut rt = Runtime::from_backend(client);
    let synth = if replicas > 1 && synth.model.replication.is_none() {
        synth.replicated(replicas)?
    } else {
        synth.clone()
    };
    synth.install(&mut rt)?;
    let data = synth.data(cfg.seed ^ 0xDA7A);
    Trainer::new(rt, synth.model.clone(), strategy(), data, cfg)
}

/// Bitwise comparison of two trainers' full host-visible state.
fn assert_trainers_match(a: &mut Trainer, b: &mut Trainer, tag: &str) {
    a.sync_host().unwrap();
    b.sync_host().unwrap();
    for (ea, eb) in a.store.entries.iter().zip(&b.store.entries) {
        assert_eq!(ea.values, eb.values, "{tag}: params diverged on {}", ea.spec.name);
        match (&ea.masks, &eb.masks) {
            (Some(ma), Some(mb)) => {
                assert_eq!(ma.fwd(), mb.fwd(), "{tag}: fwd mask {}", ea.spec.name);
                assert_eq!(ma.bwd(), mb.bwd(), "{tag}: bwd mask {}", ea.spec.name);
            }
            (None, None) => {}
            _ => panic!("{tag}: mask presence mismatch"),
        }
    }
    assert_eq!(a.opt_slots(), b.opt_slots(), "{tag}: optimiser state");
}

/// Run `steps` on both trainers, asserting per-step loss parity.
fn train_in_lockstep(clean: &mut Trainer, faulted: &mut Trainer, tag: &str) {
    let steps = clean.cfg.steps;
    for s in 0..steps {
        let a = clean.train_step().unwrap();
        let b = faulted.train_step().unwrap();
        assert_eq!(
            a.to_bits(),
            b.to_bits(),
            "{tag}: loss diverged at step {s} ({a} vs {b})"
        );
    }
}

/// How many faults the trainer's fault-wrapped client has injected.
fn faults_fired(t: &Trainer) -> usize {
    t.runtime
        .client()
        .as_faulty()
        .expect("trainer was built on a FaultBackend")
        .faults_fired()
}

// ---------------------------------------------------------------------
// 1. chaos parity: transient faults recover bitwise
// ---------------------------------------------------------------------

/// The transient plans under test. Seeds are only starting points: the
/// test bumps them until the plan both (a) lets construction through
/// (transfer faults can hit the initial upload, which `Session` would
/// surface as a build error, not silently absorb) and (b) actually
/// fires at least one fault mid-run. Every probed run is held to full
/// parity either way.
fn transient_plans() -> Vec<(String, usize)> {
    let mut plans = vec![
        // exec faults only: every fault lands on a donated train
        // execute, forcing the journal rebuild-and-replay path
        ("seed=3;exec=0.5;max=6".to_string(), 3),
        // mixed: transfer faults hit refresh gathers / scatter installs
        // and checkpoint syncs alongside the execute faults
        ("seed=7;transfer=0.1;exec=0.2;max=10".to_string(), 2),
        // dense refresh cadence, tighter fault budget
        ("seed=11;exec=0.35;max=4".to_string(), 1),
    ];
    // CI fault-seed axis: TOPKAST_FAULTS, when set, must be a transient
    // plan (no `lose` — this test runs a single device)
    if let Ok(text) = std::env::var("TOPKAST_FAULTS") {
        if !text.is_empty() {
            plans.push((text, 3));
        }
    }
    plans
}

#[test]
fn faulted_runs_recover_bitwise_identical_to_clean_runs() {
    let synth = Synthetic::tiny();
    for (text, refresh_every) in transient_plans() {
        let base = FaultPlan::parse(&text).unwrap();
        assert!(base.lose.is_none(), "transient plans only here: {text}");
        let mut fired = false;
        for bump in 0..16u64 {
            let plan = FaultPlan { seed: base.seed.wrapping_add(bump), ..base.clone() };
            let run_cfg = cfg(12, refresh_every, 5, 1);
            let mut faulted = match faulty_trainer(&synth, run_cfg.clone(), plan) {
                Ok(t) => t,
                Err(err) => {
                    // a transfer fault hit the initial upload — a build
                    // error by design, never a silent half-built chain
                    assert!(
                        RuntimeError::is_fault(&err),
                        "{text}+{bump}: construction failed non-fault: {err:#}"
                    );
                    continue;
                }
            };
            let mut clean = synth.trainer(strategy(), run_cfg).unwrap();
            let tag = format!("plan {text} (seed+{bump})");
            train_in_lockstep(&mut clean, &mut faulted, &tag);
            // eval retries in place across faults, bit-identically
            let ea = clean.evaluate().unwrap();
            let eb = faulted.evaluate().unwrap();
            assert_eq!(ea.loss_mean.to_bits(), eb.loss_mean.to_bits(), "{tag}: eval");
            assert_trainers_match(&mut faulted, &mut clean, &tag);
            if faults_fired(&faulted) > 0 {
                let stats = faulted.recovery_stats();
                assert!(
                    stats.recoveries > 0,
                    "{tag}: faults fired but nothing recovered"
                );
                fired = true;
                break;
            }
        }
        assert!(fired, "plan {text}: no probed seed fired a fault in 16 tries");
    }
}

// ---------------------------------------------------------------------
// 2. permanent device loss: quarantine + re-shard, still bitwise
// ---------------------------------------------------------------------

#[test]
fn device_loss_mid_run_reshards_to_survivors_without_diverging() {
    let synth = Synthetic::tiny();
    // 2 replicas: the lone survivor carries both shards (degenerate
    // exchange). 3 replicas: the two survivors keep running the sparse
    // gradient all-reduce between themselves — the device-loss ×
    // sparse-exchange composition.
    for replicas in [2usize, 3] {
        let run_cfg = cfg(12, 3, 5, replicas);
        // Probe the loss threshold upward: small thresholds kill device
        // 1 while the initial state is still uploading (a build error);
        // the first threshold construction survives fires on device 1's
        // next op — squarely mid-run, which is the scenario under test.
        let mut proven = false;
        for at in 1..=400u64 {
            let plan = FaultPlan::parse(&format!("lose=1@{at}")).unwrap();
            let mut faulted = match faulty_trainer(&synth, run_cfg.clone(), plan) {
                Ok(t) => t,
                Err(err) => {
                    assert!(
                        RuntimeError::is_fault(&err),
                        "x{replicas} lose=1@{at}: construction failed non-fault: {err:#}"
                    );
                    continue;
                }
            };
            let mut clean = synth.trainer(strategy(), run_cfg.clone()).unwrap();
            let tag = format!("x{replicas} lose=1@{at}");
            train_in_lockstep(&mut clean, &mut faulted, &tag);
            assert_eq!(
                faulted.quarantined_devices(),
                vec![1],
                "{tag}: the armed loss must fire on the first post-build op"
            );
            assert!(faulted.recovery_stats().recoveries > 0, "{tag}: no recovery");
            // the survivors now carry the orphaned shard; lockstep must
            // stay green and the full state still matches
            faulted.verify_replica_lockstep().unwrap();
            assert_trainers_match(&mut faulted, &mut clean, &tag);
            proven = true;
            break;
        }
        assert!(
            proven,
            "x{replicas}: no loss threshold cleared construction within 400 ops"
        );
    }
}

/// Elastic join: a device lost mid-run is revived (the replacement
/// part arriving) and re-admitted with `join_replica`. The newcomer's
/// rebuild broadcast is metered exactly — dense θ + optimiser slots,
/// plus the installed masks as index lists at 4·Σ(|fwd|+|bwd|) bytes —
/// and the rejoined run continues bitwise against a clean
/// never-faulted run, replica lockstep included.
#[test]
fn rejoined_replica_receives_masks_as_index_lists_and_stays_bitwise() {
    let synth = Synthetic::tiny();
    let replicas = 3;
    let run_cfg = cfg(14, 3, 5, replicas);
    let mut proven = false;
    for at in 1..=400u64 {
        let plan = FaultPlan::parse(&format!("lose=2@{at}")).unwrap();
        let mut faulted = match faulty_trainer(&synth, run_cfg.clone(), plan) {
            Ok(t) => t,
            Err(err) => {
                assert!(
                    RuntimeError::is_fault(&err),
                    "lose=2@{at}: construction failed non-fault: {err:#}"
                );
                continue;
            }
        };
        let mut clean = synth.trainer(strategy(), run_cfg.clone()).unwrap();
        let tag = format!("join after lose=2@{at}");
        // first stretch: the armed loss fires on device 2's first
        // post-build op; the survivors re-shard and stay bitwise
        for s in 0..7 {
            let a = clean.train_step().unwrap();
            let b = faulted.train_step().unwrap();
            assert_eq!(a.to_bits(), b.to_bits(), "{tag}: loss diverged at step {s}");
        }
        assert_eq!(faulted.quarantined_devices(), vec![2], "{tag}");

        // full-sync point: the journal is dropped behind the new
        // recovery base, so the join below replays nothing — its
        // traffic is the rebuild broadcast alone
        faulted.sync_host().unwrap();
        let mask_bytes: u64 = faulted
            .store
            .entries
            .iter()
            .filter_map(|e| e.masks.as_ref())
            .map(|m| 4 * (m.fwd().len() + m.bwd().len()) as u64)
            .sum();
        let param_bytes: u64 = faulted
            .store
            .entries
            .iter()
            .map(|e| 4 * e.values.len() as u64)
            .sum();
        let opt_bytes: u64 =
            faulted.opt_slots().iter().map(|s| 4 * s.len() as u64).sum();

        // the replacement device arrives; the trainer re-admits it
        faulted
            .runtime
            .client()
            .as_faulty()
            .expect("trainer was built on a FaultBackend")
            .revive_device(2);
        let before = faulted.runtime.device_transfer_stats(2).unwrap();
        faulted.join_replica(2).unwrap();
        assert!(faulted.quarantined_devices().is_empty(), "{tag}");
        let moved =
            faulted.runtime.device_transfer_stats(2).unwrap().since(&before);
        assert_eq!(
            moved.h2d_bytes,
            param_bytes + opt_bytes + mask_bytes,
            "{tag}: the newcomer receives θ + opt dense and the masks as \
             index lists (4·Σ(|fwd|+|bwd|) = {mask_bytes} bytes)"
        );
        assert_eq!(moved.d2h_bytes, 0, "{tag}: a join is upload-only");

        // the rejoined set resumes on the full shard geometry, bitwise
        for s in 7..run_cfg.steps {
            let a = clean.train_step().unwrap();
            let b = faulted.train_step().unwrap();
            assert_eq!(
                a.to_bits(),
                b.to_bits(),
                "{tag}: post-join loss diverged at step {s}"
            );
        }
        faulted.verify_replica_lockstep().unwrap();
        assert_trainers_match(&mut faulted, &mut clean, &tag);
        proven = true;
        break;
    }
    assert!(proven, "no loss threshold cleared construction within 400 ops");
}

// ---------------------------------------------------------------------
// 3. serve degradation
// ---------------------------------------------------------------------

/// The deterministic eval stream as flat request rows (serve_plane's
/// idiom): one `(x_row, y)` per example, in eval-batch order.
fn eval_requests(synth: &Synthetic, seed: u64) -> Vec<(Vec<f32>, f32)> {
    let mut data = synth.data(seed ^ 0xDA7A);
    let batch = synth.model.batch_size();
    let mut rows = Vec::new();
    let mut idx = 0;
    while let Some((x, y)) = data.eval_batch(idx) {
        let xs = x.as_f32().unwrap();
        let ys = y.as_f32().unwrap();
        let row_len = xs.len() / batch;
        for slot in 0..batch {
            rows.push((xs[slot * row_len..(slot + 1) * row_len].to_vec(), ys[slot]));
        }
        idx += 1;
    }
    rows
}

fn serve_stream(server: &mut ModelServer, rows: &[(Vec<f32>, f32)]) -> Vec<Completion> {
    for (x, y) in rows {
        server.submit(x.clone(), *y).unwrap();
    }
    server.drain().unwrap()
}

/// Logits/ids must agree completion-for-completion; placement (device)
/// may legitimately differ once a fault moved a batch. Ids are compared
/// relative to each pass's first id, so two passes over one server (its
/// id counter never resets) compare the same as two fresh servers.
fn assert_completions_match(a: &[Completion], b: &[Completion], tag: &str) {
    assert_eq!(a.len(), b.len(), "{tag}: completion count");
    let base = |cs: &[Completion]| {
        cs.iter()
            .flat_map(|c| c.request_ids.iter().copied())
            .min()
            .unwrap_or(0)
    };
    let (base_a, base_b) = (base(a), base(b));
    for (ca, cb) in a.iter().zip(b) {
        let ids_a: Vec<u64> = ca.request_ids.iter().map(|id| id - base_a).collect();
        let ids_b: Vec<u64> = cb.request_ids.iter().map(|id| id - base_b).collect();
        assert_eq!(ids_a, ids_b, "{tag}: request ids");
        assert_eq!(ca.padded, cb.padded, "{tag}: padding");
        assert_eq!(ca.loss.to_bits(), cb.loss.to_bits(), "{tag}: loss bits");
        assert_eq!(ca.metric.to_bits(), cb.metric.to_bits(), "{tag}: metric bits");
    }
}

/// A trained checkpoint pair from one run: (mid-run, successor).
fn checkpoint_pair(
    synth: &Synthetic,
    seed: u64,
) -> (topkast::coordinator::Checkpoint, topkast::coordinator::Checkpoint) {
    let mut t = synth.trainer(strategy(), cfg(16, 3, seed, 1)).unwrap();
    for _ in 0..8 {
        t.train_step().unwrap();
    }
    let a = t.capture_checkpoint().unwrap();
    for _ in 8..16 {
        t.train_step().unwrap();
    }
    let b = t.capture_checkpoint().unwrap();
    (a, b)
}

fn server_with_plan(
    synth: &Synthetic,
    ck: &topkast::coordinator::Checkpoint,
    devices: usize,
    serve_cfg: ServeConfig,
    plan: Option<FaultPlan>,
) -> anyhow::Result<ModelServer> {
    let mut client = AnyBackend::from_env(devices)?;
    if let Some(plan) = plan {
        client = AnyBackend::faulty(client, plan);
    }
    let mut rt = Runtime::from_backend(client);
    synth.install(&mut rt)?;
    ModelServer::from_checkpoint(rt, synth.model.clone(), ck, serve_cfg)
}

#[test]
fn serve_answers_every_request_bitwise_under_exec_faults() {
    let synth = Synthetic::tiny();
    let (ck, _) = checkpoint_pair(&synth, 9);
    // three passes over the eval stream: enough executions that an
    // exec-fault plan reliably fires
    let mut rows = eval_requests(&synth, 9);
    let once = rows.clone();
    for _ in 0..2 {
        rows.extend(once.iter().cloned());
    }
    let mut reference =
        server_with_plan(&synth, &ck, 2, ServeConfig::default(), None).unwrap();
    let want = serve_stream(&mut reference, &rows);

    let mut fired = false;
    for seed in 0..32u64 {
        // exec faults only: installs are transfer ops, so the server
        // always stands up; faults land on live executions where
        // execute_with_failover must retry without changing one bit
        let plan = FaultPlan::parse(&format!("seed={seed};exec=0.5;max=6")).unwrap();
        let mut server =
            server_with_plan(&synth, &ck, 2, ServeConfig::default(), Some(plan))
                .unwrap();
        let got = serve_stream(&mut server, &rows);
        let tag = format!("exec plan seed={seed}");
        assert_completions_match(&got, &want, &tag);
        let stats = server.stats();
        assert_eq!(stats.completed, rows.len() as u64, "{tag}: all answered");
        assert_eq!(stats.shed, 0, "{tag}: nothing shed on an unbounded queue");
        if stats.exec_retries > 0 {
            fired = true;
            break;
        }
    }
    assert!(fired, "no exec-fault seed fired a retry in 32 tries");
}

#[test]
fn bounded_queue_sheds_past_capacity_and_deadline_expires_stale_requests() {
    let synth = Synthetic::tiny();
    let (ck, _) = checkpoint_pair(&synth, 3);
    let rows = eval_requests(&synth, 3);

    // bounded admission: cap + 2 submissions → exactly 2 explicit sheds
    let batch = synth.model.batch_size();
    let cap = batch + 2;
    assert!(rows.len() >= cap + 2, "eval stream too short for the cap test");
    let serve_cfg = ServeConfig { queue_cap: cap, ..ServeConfig::default() };
    let mut server = server_with_plan(&synth, &ck, 1, serve_cfg, None).unwrap();
    for (i, (x, y)) in rows.iter().take(cap + 2).enumerate() {
        let result = server.submit(x.clone(), *y);
        if i < cap {
            result.unwrap();
        } else {
            let err = result.expect_err("submission past queue_cap must shed");
            assert!(Shed::is_shed(&err), "not a shed error: {err:#}");
        }
    }
    assert_eq!(server.stats().shed, 2);
    let done = server.drain().unwrap();
    let served: usize = done.iter().map(|c| c.request_ids.len()).sum();
    assert_eq!(served, cap, "every admitted request answered, shed ones not");
    assert_eq!(server.stats().completed, cap as u64);

    // deadline degradation: one batch launches, everything still queued
    // two ticks later is expired rather than served late
    let serve_cfg = ServeConfig {
        inflight_limit: 1,
        deadline_ticks: 1,
        ..ServeConfig::default()
    };
    let mut server = server_with_plan(&synth, &ck, 1, serve_cfg, None).unwrap();
    let backlog = 4 * batch;
    for (x, y) in rows.iter().cycle().take(backlog) {
        server.submit(x.clone(), *y).unwrap();
    }
    server.tick().unwrap(); // admits exactly one batch (inflight_limit)
    server.tick().unwrap(); // retires it; the rest are now past deadline
    assert_eq!(server.stats().expired, (backlog - batch) as u64);
    assert_eq!(server.stats().completed, batch as u64);
    assert!(server.drain().unwrap().is_empty(), "expired requests never serve");
}

#[test]
fn mid_swap_device_loss_aborts_and_keeps_the_old_checkpoint_serving() {
    let synth = Synthetic::tiny();
    let (ck_a, ck_b) = checkpoint_pair(&synth, 7);
    assert_ne!(ck_a.step, ck_b.step);
    let rows = eval_requests(&synth, 7);

    // Probe the loss threshold upward until it lands inside the swap:
    // below the window, construction or pre-swap traffic absorbs the
    // loss (quarantine before the swap — skipped); the first threshold
    // past clean pre-traffic fires on the swap's own scatter ops.
    let mut proven = false;
    for at in 1..=400u64 {
        let plan = FaultPlan::parse(&format!("lose=0@{at}")).unwrap();
        let mut server = match server_with_plan(
            &synth,
            &ck_a,
            2,
            ServeConfig::default(),
            Some(plan),
        ) {
            Ok(s) => s,
            Err(err) => {
                assert!(
                    RuntimeError::is_fault(&err),
                    "lose=0@{at}: construction failed non-fault: {err:#}"
                );
                continue;
            }
        };
        let before = serve_stream(&mut server, &rows);
        if !server.quarantined_devices().is_empty() {
            continue; // the loss fired during pre-swap traffic
        }
        match CheckpointSwapper::new().swap(&mut server, &ck_b) {
            Ok(_) => continue, // threshold beyond the swap window
            Err(err) => {
                let tag = format!("lose=0@{at}");
                assert!(
                    format!("{err:#}").contains("still serving"),
                    "{tag}: abort error names the surviving checkpoint: {err:#}"
                );
                // the old checkpoint is still installed and still
                // answers — bit-for-bit what it served before the
                // aborted swap, now from the surviving device
                assert_eq!(server.installed_step(), ck_a.step, "{tag}");
                assert_eq!(server.quarantined_devices(), vec![0], "{tag}");
                let after = serve_stream(&mut server, &rows);
                assert_completions_match(&after, &before, &tag);
                proven = true;
                break;
            }
        }
    }
    assert!(proven, "no loss threshold landed inside the swap within 400 ops");
}
