//! Integration: the full coordinator over real artifacts, constructed
//! through the `Session`/`RunSpec` API — learning progress, the
//! Top-KAST invariants across a whole run, the RigL grad-norms path,
//! refresh-period robustness, checkpointing, async refresh, and the
//! observer hooks.
//!
//! All tests skip (with a note) when `make artifacts` has not been
//! run, so artifact-less environments (CI) stay green on the
//! host-only suites.

use topkast::api::{JsonlMetrics, PeriodicCheckpoint, RunSpec, Session};
use topkast::coordinator::{Checkpoint, LrSchedule};
use topkast::runtime::Manifest;
use topkast::util::json::Json;

/// The manifest, or an early `return` that skips the calling test
/// when artifacts are not built.
macro_rules! require_artifacts {
    () => {
        match Manifest::load(concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts")) {
            Ok(man) => man,
            Err(_) => {
                eprintln!("skipping: artifacts not built (run `make artifacts`)");
                return;
            }
        }
    };
}

fn spec(
    model: &str,
    strategy: &str,
    steps: usize,
    refresh_every: usize,
    seed: u64,
) -> RunSpec {
    let lr = if model.starts_with("lm") {
        LrSchedule::WarmupCosine { base: 3e-3, warmup: 10, floor: 1e-5 }
    } else {
        LrSchedule::Constant { base: 0.1 }
    };
    RunSpec::run(model, strategy, steps)
        .lr(lr)
        .refresh_every(refresh_every)
        .churn_every(20)
        .seed(seed)
}

fn session(
    man: &Manifest,
    model: &str,
    strategy: &str,
    steps: usize,
    refresh_every: usize,
    seed: u64,
) -> Session {
    Session::builder()
        .manifest(man)
        .spec(spec(model, strategy, steps, refresh_every, seed))
        .quiet()
        .build()
        .unwrap()
}

#[test]
fn topkast_learns_on_mlp() {
    let man = require_artifacts!();
    let mut s = session(&man, "mlp_tiny", "topkast:0.8,0.5", 150, 10, 1);
    s.train().unwrap();
    let first = s.trainer.metrics.losses[0].1;
    let last = s.trainer.metrics.tail_loss(10).unwrap();
    assert!(last < first * 0.8, "no learning: first {first} last {last}");
    let ev = s.evaluate().unwrap();
    assert!(ev.accuracy > 0.3, "eval accuracy {}", ev.accuracy);
}

#[test]
fn mask_invariants_hold_across_whole_run() {
    let man = require_artifacts!();
    let mut s = session(&man, "mlp_tiny", "topkast:0.8,0.5", 60, 5, 2);
    for _ in 0..60 {
        s.trainer.train_step().unwrap();
        for e in &s.trainer.store.entries {
            if let Some(m) = &e.masks {
                assert!(m.is_nested(), "A ⊄ B at step {}", s.trainer.step);
                let n = e.values.len();
                let ka = topkast::sparsity::topk::k_for_density(n, 0.2);
                let kb = topkast::sparsity::topk::k_for_density(n, 0.5);
                assert_eq!(m.fwd_nnz(), ka, "fwd count drifted");
                assert_eq!(m.bwd_nnz(), kb, "bwd count drifted");
            }
        }
    }
}

#[test]
fn rigl_runs_grad_norms_and_learns() {
    let man = require_artifacts!();
    // refresh gate every step; RigL's own wants_update throttles
    let mut s = session(&man, "mlp_tiny", "rigl:0.8,0.3,10", 100, 1, 3);
    s.train().unwrap();
    let first = s.trainer.metrics.losses[0].1;
    let last = s.trainer.metrics.tail_loss(10).unwrap();
    assert!(last < first, "RigL failed to learn: {first} -> {last}");
    // density must be preserved through drop/grow cycles
    for e in &s.trainer.store.entries {
        if let Some(m) = &e.masks {
            let n = e.values.len();
            let k = topkast::sparsity::topk::k_for_density(n, 0.2);
            assert_eq!(m.fwd_nnz(), k);
        }
    }
}

#[test]
fn refresh_period_does_not_break_training() {
    let man = require_artifacts!();
    // Appendix C / Table 6: infrequent top-k refresh must still train.
    let mut fin = vec![];
    for n in [1usize, 25] {
        let mut s = session(&man, "mlp_tiny", "topkast:0.8,0.5", 150, n, 4);
        s.train().unwrap();
        fin.push(s.trainer.metrics.tail_loss(10).unwrap());
    }
    let (n1, n25) = (fin[0], fin[1]);
    assert!(
        (n1 - n25).abs() < n1 * 0.5,
        "N=25 collapsed training: N=1 {n1} vs N=25 {n25}"
    );
}

#[test]
fn lm_trainer_reports_bpc() {
    let man = require_artifacts!();
    let mut s = session(&man, "lm_tiny", "topkast:0.8,0.5", 80, 10, 5);
    s.train().unwrap();
    let ev = s.evaluate().unwrap();
    assert!(ev.bpc.is_finite() && ev.bpc > 0.0);
    // after 80 steps the model must beat the uniform bound log2(96)=6.58
    assert!(ev.bpc < 6.58, "bpc {} not below uniform", ev.bpc);
    assert!(ev.accuracy.is_nan(), "LM eval reports bpc, not accuracy");
}

#[test]
fn churn_decreases_and_reservoir_small() {
    let man = require_artifacts!();
    // Fig 3 qualitative claims on a real (short) run.
    let mut s = session(&man, "cnn_tiny", "topkast:0.8,0.5", 200, 1, 6);
    s.train().unwrap();
    let churn = s.trainer.metrics.churn.summary();
    assert!(churn.len() >= 3);
    let early = churn[1].2; // mean churn, first measured interval
    let late = churn.last().unwrap().2;
    assert!(
        late <= early,
        "mask churn should not grow over training: early {early} late {late}"
    );
    let woken = s.trainer.metrics.reservoir.final_fraction().unwrap();
    assert!(
        woken < 0.5,
        "most of the reservoir should stay asleep, got {woken}"
    );
}

#[test]
fn checkpoint_roundtrip_through_session() {
    let man = require_artifacts!();
    let mut s = session(&man, "mlp_tiny", "topkast:0.8,0.5", 40, 10, 7);
    s.train().unwrap();
    let ev1 = s.evaluate().unwrap();

    let dir = std::env::temp_dir().join("topkast_it_ck");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("t.ckpt");
    s.save_checkpoint(&path).unwrap();

    // fresh session, same data seed → same eval stream; restoring the
    // checkpoint must reproduce the evaluation exactly
    let mut s2 = session(&man, "mlp_tiny", "topkast:0.8,0.5", 40, 10, 7);
    s2.restore_checkpoint(&path).unwrap();
    assert_eq!(s2.trainer.step, 40, "restore resumes the step counter");
    let ev2 = s2.evaluate().unwrap();
    assert!(
        (ev1.loss_mean - ev2.loss_mean).abs() < 1e-6,
        "restored eval diverged: {} vs {}",
        ev1.loss_mean,
        ev2.loss_mean
    );
}

#[test]
fn async_refresh_trains_equivalently() {
    let man = require_artifacts!();
    // §2.4 overlap mode: stale masks from the background worker must
    // not break training (the Table-6 staleness-tolerance claim). The
    // worker's second strategy instance comes from the registry — the
    // spec just flips async_refresh on.
    let mut sync_s = session(&man, "mlp_tiny", "topkast:0.8,0.5", 120, 10, 11);
    sync_s.train().unwrap();
    let sync_loss = sync_s.trainer.metrics.tail_loss(10).unwrap();

    let mut async_s = Session::builder()
        .manifest(&man)
        .spec(spec("mlp_tiny", "topkast:0.8,0.5", 120, 10, 11).async_refresh(true))
        .quiet()
        .build()
        .unwrap();
    async_s.train().unwrap();
    let async_loss = async_s.trainer.metrics.tail_loss(10).unwrap();
    let applied = async_s.trainer.async_refreshes_applied().unwrap();

    assert!(applied >= 2, "worker never delivered masks ({applied})");
    assert!(
        (async_loss - sync_loss).abs() < sync_loss * 0.5,
        "async refresh diverged: sync {sync_loss} vs async {async_loss}"
    );
    // invariants still hold under stale masks
    for e in &async_s.trainer.store.entries {
        if let Some(m) = &e.masks {
            assert!(m.is_nested());
        }
    }
}

#[test]
fn seeds_reproduce_runs_exactly() {
    let man = require_artifacts!();
    let run = |seed| {
        let mut s = session(&man, "mlp_tiny", "topkast:0.8,0.5", 30, 5, seed);
        s.train().unwrap();
        s.trainer.metrics.losses.clone()
    };
    assert_eq!(run(9), run(9), "same seed must give identical loss traces");
    assert_ne!(run(9), run(10), "different seeds must differ");
}

#[test]
fn observers_stream_metrics_and_checkpoints() {
    let man = require_artifacts!();
    let dir = std::env::temp_dir().join("topkast_it_obs");
    std::fs::create_dir_all(&dir).unwrap();
    let jsonl = dir.join("metrics.jsonl");
    let ckpt = dir.join("periodic.ckpt");
    let _ = std::fs::remove_file(&jsonl);
    let _ = std::fs::remove_file(&ckpt);

    let mut s = Session::builder()
        .manifest(&man)
        .spec(
            spec("mlp_tiny", "topkast:0.8,0.5", 30, 10, 12)
                .eval_every(15)
                .eval_batches(2),
        )
        .quiet()
        .observer(Box::new(JsonlMetrics::create(&jsonl).unwrap()))
        .observer(Box::new(PeriodicCheckpoint::every(10, &ckpt)))
        .build()
        .unwrap();
    s.train().unwrap();

    // checkpoint observer wrote the final state
    assert_eq!(Checkpoint::load(&ckpt).unwrap().step, 30);

    // JSONL stream: one parseable object per line; 30 steps + refreshes
    // + 2 evals + end
    let text = std::fs::read_to_string(&jsonl).unwrap();
    let mut steps = 0;
    let mut refreshes = 0;
    let mut evals = 0;
    let mut ends = 0;
    for line in text.lines() {
        let j = Json::parse(line).unwrap_or_else(|e| panic!("bad line {line:?}: {e}"));
        match j.get("event").unwrap().as_str().unwrap() {
            "step" => steps += 1,
            "refresh" => refreshes += 1,
            "eval" => evals += 1,
            "end" => ends += 1,
            other => panic!("unknown event {other:?}"),
        }
    }
    assert_eq!(steps, 30);
    assert!(refreshes >= 3, "refresh every 10 over 30 steps, got {refreshes}");
    assert_eq!(evals, 2);
    assert_eq!(ends, 1);
}

#[test]
fn config_file_builds_a_session() {
    let man = require_artifacts!();
    // a JSON config is a first-class entry surface
    let dir = std::env::temp_dir().join("topkast_it_cfg");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("run.json");
    std::fs::write(
        &path,
        r#"{"model": "mlp_tiny", "strategy": "topkast:0.8,0.5",
            "steps": 5, "refresh_every": 5, "seed": 1}"#,
    )
    .unwrap();
    let mut s = Session::builder()
        .manifest(&man)
        .config_file(path.to_str().unwrap())
        .unwrap()
        .quiet()
        .build()
        .unwrap();
    s.train().unwrap();
    assert_eq!(s.trainer.step, 5);
}
