//! Integration: the full coordinator (Trainer) over real artifacts —
//! learning progress, the Top-KAST invariants across a whole run, the
//! RigL grad-norms path, refresh-period robustness and checkpointing.

use topkast::coordinator::{
    source_for, Checkpoint, LrSchedule, Trainer, TrainerConfig,
};
use topkast::runtime::{Manifest, Runtime};
use topkast::sparsity::{MaskStrategy, RigL, TopKast};

fn manifest() -> Manifest {
    Manifest::load(concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts"))
        .expect("run `make artifacts` before cargo test")
}

fn trainer(
    model: &str,
    strategy: Box<dyn MaskStrategy>,
    steps: usize,
    refresh_every: usize,
    seed: u64,
) -> Trainer {
    let man = manifest();
    let m = man.model(model).unwrap().clone();
    let cfg = TrainerConfig {
        steps,
        lr: match m.kind.as_str() {
            "lm" => LrSchedule::WarmupCosine { base: 3e-3, warmup: 10, floor: 1e-5 },
            _ => LrSchedule::Constant { base: 0.1 },
        },
        refresh_every,
        churn_every: 20,
        seed,
        log_every: usize::MAX,
        ..Default::default()
    };
    let runtime = Runtime::new().unwrap();
    let data = source_for(&m, seed ^ 0xDA7A).unwrap();
    Trainer::new(runtime, m, strategy, data, cfg).unwrap()
}

#[test]
fn topkast_learns_on_mlp() {
    let mut t = trainer(
        "mlp_tiny",
        Box::new(TopKast::from_sparsities(0.8, 0.5)),
        150,
        10,
        1,
    );
    t.train().unwrap();
    let first = t.metrics.losses[0].1;
    let last = t.metrics.tail_loss(10).unwrap();
    assert!(
        last < first * 0.8,
        "no learning: first {first} last {last}"
    );
    let ev = t.evaluate().unwrap();
    assert!(ev.accuracy > 0.3, "eval accuracy {}", ev.accuracy);
}

#[test]
fn mask_invariants_hold_across_whole_run() {
    let mut t = trainer(
        "mlp_tiny",
        Box::new(TopKast::from_sparsities(0.8, 0.5)),
        60,
        5,
        2,
    );
    for _ in 0..60 {
        t.train_step().unwrap();
        for e in &t.store.entries {
            if let Some(m) = &e.masks {
                assert!(m.is_nested(), "A ⊄ B at step {}", t.step);
                let n = e.values.len();
                let ka = topkast::sparsity::topk::k_for_density(n, 0.2);
                let kb = topkast::sparsity::topk::k_for_density(n, 0.5);
                assert_eq!(m.fwd_nnz(), ka, "fwd count drifted");
                assert_eq!(m.bwd_nnz(), kb, "bwd count drifted");
            }
        }
    }
}

#[test]
fn rigl_runs_grad_norms_and_learns() {
    let mut t = trainer(
        "mlp_tiny",
        Box::new(RigL::new(0.2, 0.3, 10)),
        100,
        1, // refresh gate every step; RigL's own wants_update throttles
        3,
    );
    t.train().unwrap();
    let first = t.metrics.losses[0].1;
    let last = t.metrics.tail_loss(10).unwrap();
    assert!(last < first, "RigL failed to learn: {first} -> {last}");
    // density must be preserved through drop/grow cycles
    for e in &t.store.entries {
        if let Some(m) = &e.masks {
            let n = e.values.len();
            let k = topkast::sparsity::topk::k_for_density(n, 0.2);
            assert_eq!(m.fwd_nnz(), k);
        }
    }
}

#[test]
fn refresh_period_does_not_break_training() {
    // Appendix C / Table 6: infrequent top-k refresh must still train.
    let mut fin = vec![];
    for n in [1usize, 25] {
        let mut t = trainer(
            "mlp_tiny",
            Box::new(TopKast::from_sparsities(0.8, 0.5)),
            150,
            n,
            4,
        );
        t.train().unwrap();
        fin.push(t.metrics.tail_loss(10).unwrap());
    }
    let (n1, n25) = (fin[0], fin[1]);
    assert!(
        (n1 - n25).abs() < n1 * 0.5,
        "N=25 collapsed training: N=1 {n1} vs N=25 {n25}"
    );
}

#[test]
fn lm_trainer_reports_bpc() {
    let mut t = trainer(
        "lm_tiny",
        Box::new(TopKast::from_sparsities(0.8, 0.5)),
        80,
        10,
        5,
    );
    t.train().unwrap();
    let ev = t.evaluate().unwrap();
    assert!(ev.bpc.is_finite() && ev.bpc > 0.0);
    // after 80 steps the model must beat the uniform bound log2(96)=6.58
    assert!(ev.bpc < 6.58, "bpc {} not below uniform", ev.bpc);
    assert!(ev.accuracy.is_nan(), "LM eval reports bpc, not accuracy");
}

#[test]
fn churn_decreases_and_reservoir_small() {
    // Fig 3 qualitative claims on a real (short) run.
    let mut t = trainer(
        "cnn_tiny",
        Box::new(TopKast::from_sparsities(0.8, 0.5)),
        200,
        1,
        6,
    );
    t.train().unwrap();
    let churn = t.metrics.churn.summary();
    assert!(churn.len() >= 3);
    let early = churn[1].2; // mean churn, first measured interval
    let late = churn.last().unwrap().2;
    assert!(
        late <= early,
        "mask churn should not grow over training: early {early} late {late}"
    );
    let woken = t.metrics.reservoir.final_fraction().unwrap();
    assert!(
        woken < 0.5,
        "most of the reservoir should stay asleep, got {woken}"
    );
}

#[test]
fn checkpoint_roundtrip_through_trainer() {
    let mut t = trainer(
        "mlp_tiny",
        Box::new(TopKast::from_sparsities(0.8, 0.5)),
        40,
        10,
        7,
    );
    t.train().unwrap();
    let ev1 = t.evaluate().unwrap();

    let dir = std::env::temp_dir().join("topkast_it_ck");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("t.ckpt");
    Checkpoint::capture(&t.store, &[], t.step).save(&path).unwrap();

    // fresh trainer, different seed → different init; restoring the
    // checkpoint must reproduce the evaluation exactly
    let mut t2 = trainer(
        "mlp_tiny",
        Box::new(TopKast::from_sparsities(0.8, 0.5)),
        40,
        10,
        7, // same data seed → same eval stream
    );
    let ck = Checkpoint::load(&path).unwrap();
    ck.restore(&mut t2.store, &mut []).unwrap();
    let ev2 = t2.evaluate().unwrap();
    assert!(
        (ev1.loss_mean - ev2.loss_mean).abs() < 1e-6,
        "restored eval diverged: {} vs {}",
        ev1.loss_mean,
        ev2.loss_mean
    );
}

#[test]
fn async_refresh_trains_equivalently() {
    // §2.4 overlap mode: stale masks from the background worker must
    // not break training (the Table-6 staleness-tolerance claim).
    let mut sync_t = trainer(
        "mlp_tiny",
        Box::new(TopKast::from_sparsities(0.8, 0.5)),
        120,
        10,
        11,
    );
    sync_t.train().unwrap();
    let sync_loss = sync_t.metrics.tail_loss(10).unwrap();

    let mut async_t = trainer(
        "mlp_tiny",
        Box::new(TopKast::from_sparsities(0.8, 0.5)),
        120,
        10,
        11,
    );
    async_t
        .enable_async_refresh(Box::new(TopKast::from_sparsities(0.8, 0.5)))
        .unwrap();
    async_t.train().unwrap();
    let async_loss = async_t.metrics.tail_loss(10).unwrap();
    let applied = async_t.async_refreshes_applied().unwrap();

    assert!(applied >= 2, "worker never delivered masks ({applied})");
    assert!(
        (async_loss - sync_loss).abs() < sync_loss * 0.5,
        "async refresh diverged: sync {sync_loss} vs async {async_loss}"
    );
    // invariants still hold under stale masks
    for e in &async_t.store.entries {
        if let Some(m) = &e.masks {
            assert!(m.is_nested());
        }
    }
}

#[test]
fn seeds_reproduce_runs_exactly() {
    let run = |seed| {
        let mut t = trainer(
            "mlp_tiny",
            Box::new(TopKast::from_sparsities(0.8, 0.5)),
            30,
            5,
            seed,
        );
        t.train().unwrap();
        t.metrics.losses.clone()
    };
    assert_eq!(run(9), run(9), "same seed must give identical loss traces");
    assert_ne!(run(9), run(10), "different seeds must differ");
}
