//! Parity + traffic tests for the device-resident training loop.
//!
//! The device path (persistent `PjRtBuffer`s, step-N outputs chained
//! into step-N+1 inputs, loss-only downloads) must be *bit-identical*
//! to a host-round-trip reference loop that uploads and downloads
//! everything each step — same losses, same params, same masks, same
//! optimiser state — across ≥3 refresh cycles, through the §2.4 async
//! refresher, and across a checkpoint save/restore mid-run.
//!
//! The traffic tests pin the acceptance criterion directly against the
//! runtime's metered transfer counters: a steady-state step moves only
//! the batch + step scalars up and the loss scalar down.

use topkast::coordinator::{
    AsyncMaskRefresher, DataSource, Trainer, TrainerConfig,
};
use topkast::runtime::client::TensorRef;
use topkast::runtime::{Runtime, Synthetic};
use topkast::sparsity::{
    update_store_masks, MaskStrategy, ParamStore, TopKast,
};
use topkast::util::rng::Pcg64;

fn cfg(steps: usize, refresh_every: usize, seed: u64) -> TrainerConfig {
    TrainerConfig { steps, refresh_every, seed, ..TrainerConfig::default() }
}

fn strategy() -> Box<TopKast> {
    Box::new(TopKast::from_sparsities(0.8, 0.5))
}

/// The pre-device-resident trainer, reimplemented over the
/// host-round-trip path (`run_borrowed`): every step uploads
/// θ/masks/opt and downloads θ'/opt'/loss. The refresh scheduling, RNG
/// streams, scalar marshalling and data wiring replicate `Trainer`
/// exactly, so any divergence is the device residency itself.
struct HostLoop {
    rt: Runtime,
    synth: Synthetic,
    store: ParamStore,
    opt: Vec<Vec<f32>>,
    strategy: Box<dyn MaskStrategy>,
    rng: Pcg64,
    data: Box<dyn DataSource>,
    cfg: TrainerConfig,
    step: usize,
    refresher: Option<AsyncMaskRefresher>,
}

impl HostLoop {
    fn new(synth: &Synthetic, cfg: TrainerConfig) -> Self {
        let mut rt = Runtime::new().unwrap();
        synth.install(&mut rt).unwrap();
        let store = ParamStore::init(&synth.model.params, cfg.seed);
        let slots = synth.model.optimizer.slots();
        let opt = synth
            .model
            .params
            .iter()
            .flat_map(|p| {
                std::iter::repeat_with(move || vec![0.0f32; p.shape.numel()])
                    .take(slots)
            })
            .collect();
        let data = synth.data(cfg.seed ^ 0xDA7A);
        let rng = Pcg64::new(cfg.seed ^ 0x7A5C, 0xEE);
        HostLoop {
            rt,
            synth: synth.clone(),
            store,
            opt,
            strategy: strategy(),
            rng,
            data,
            cfg,
            step: 0,
            refresher: None,
        }
    }

    /// Mirror `Trainer::enable_async_refresh` + `set_async_blocking`.
    fn enable_blocking_async(&mut self) {
        let mut r =
            AsyncMaskRefresher::spawn(strategy(), self.cfg.seed ^ 0xA57C).unwrap();
        r.set_blocking(true);
        self.refresher = Some(r);
    }

    fn step(&mut self) -> f64 {
        let due = self.step == 0
            || (self.step % self.cfg.refresh_every == 0
                && self.strategy.wants_update(self.step, self.cfg.steps));
        if let Some(r) = self.refresher.as_mut() {
            if self.step == 0 {
                r.request(&self.store, 0, self.cfg.steps);
                r.wait_install(&mut self.store).unwrap();
            } else {
                r.try_install(&mut self.store).unwrap();
                if due {
                    r.request(&self.store, self.step, self.cfg.steps);
                }
            }
        } else if due {
            update_store_masks(
                self.strategy.as_mut(),
                &mut self.store,
                None,
                &mut self.rng,
                self.step,
                self.cfg.steps,
            )
            .unwrap();
        }

        let (x, y) = self.data.next_train();
        let lr = self.cfg.lr.at(self.step, self.cfg.steps) as f32;
        let d = self.strategy.densities(self.step, self.cfg.steps).fwd;
        let scalars: [[f32; 1]; 4] = [
            [lr],
            [(self.step + 1) as f32],
            [self.cfg.reg_scale as f32],
            [(1.0 / d.max(1e-6)) as f32],
        ];
        // the host-round-trip path is the legacy dense exchange: masks
        // are materialised from the index sets for upload
        let dense_masks: Vec<(Vec<f32>, Vec<f32>)> = self
            .store
            .entries
            .iter()
            .filter_map(|e| e.masks.as_ref().map(|m| (m.fwd_dense(), m.bwd_dense())))
            .collect();
        let mut inputs: Vec<TensorRef<'_>> = vec![];
        for e in &self.store.entries {
            inputs.push(TensorRef::F32(&e.values));
        }
        for fwd in [true, false] {
            for m in &dense_masks {
                inputs.push(TensorRef::F32(if fwd { &m.0 } else { &m.1 }));
            }
        }
        for slot in &self.opt {
            inputs.push(TensorRef::F32(slot));
        }
        inputs.push(TensorRef::from(&x));
        inputs.push(TensorRef::from(&y));
        for s in &scalars {
            inputs.push(TensorRef::F32(&s[..]));
        }

        let exe = self.rt.load(&self.synth.model.train).unwrap();
        let outs = exe.run_borrowed(&inputs).unwrap();
        drop(inputs);
        let np = self.synth.model.params.len();
        let slots = self.synth.model.optimizer.slots();
        for (i, out) in outs.iter().take(np).enumerate() {
            let name = self.synth.model.params[i].name.clone();
            self.store
                .set_values(&name, out.as_f32().unwrap().to_vec())
                .unwrap();
        }
        for (j, out) in outs[np..np + np * slots].iter().enumerate() {
            self.opt[j] = out.as_f32().unwrap().to_vec();
        }
        let loss = outs.last().unwrap().as_f32().unwrap()[0] as f64;
        self.step += 1;
        loss
    }
}

/// Bitwise comparison of the full run state.
fn assert_state_matches(trainer: &mut Trainer, host: &HostLoop, tag: &str) {
    trainer.sync_host().unwrap();
    for (a, b) in trainer.store.entries.iter().zip(&host.store.entries) {
        assert_eq!(a.values, b.values, "{tag}: params diverged on {}", a.spec.name);
        match (&a.masks, &b.masks) {
            (Some(ma), Some(mb)) => {
                assert_eq!(ma.fwd(), mb.fwd(), "{tag}: fwd mask {}", a.spec.name);
                assert_eq!(ma.bwd(), mb.bwd(), "{tag}: bwd mask {}", a.spec.name);
            }
            (None, None) => {}
            _ => panic!("{tag}: mask presence mismatch"),
        }
    }
    assert_eq!(trainer.opt_slots(), &host.opt[..], "{tag}: optimiser state");
}

#[test]
fn device_resident_matches_host_roundtrip_over_refresh_cycles() {
    for synth in [Synthetic::tiny(), Synthetic::small()] {
        // 11 steps / refresh every 3 → refreshes at 0, 3, 6, 9 (≥3 cycles)
        let cfg = cfg(11, 3, 5);
        let mut trainer = synth.trainer(strategy(), cfg.clone()).unwrap();
        let mut host = HostLoop::new(&synth, cfg.clone());
        for s in 0..cfg.steps {
            let a = trainer.train_step().unwrap();
            let b = host.step();
            assert_eq!(a, b, "{}: loss diverged at step {s}", synth.model.name);
        }
        assert_state_matches(&mut trainer, &host, &synth.model.name);
    }
}

#[test]
fn parity_holds_through_async_refresher() {
    let synth = Synthetic::tiny();
    let cfg = cfg(11, 3, 9);
    let mut trainer = synth.trainer(strategy(), cfg.clone()).unwrap();
    trainer.enable_async_refresh(strategy()).unwrap();
    trainer.set_async_blocking(true).unwrap();
    let mut host = HostLoop::new(&synth, cfg.clone());
    host.enable_blocking_async();
    for s in 0..cfg.steps {
        let a = trainer.train_step().unwrap();
        let b = host.step();
        assert_eq!(a, b, "async loss diverged at step {s}");
    }
    assert!(trainer.async_refreshes_applied().unwrap() >= 3);
    assert_state_matches(&mut trainer, &host, "async");
}

#[test]
fn parity_survives_checkpoint_restore_mid_run() {
    let synth = Synthetic::tiny();
    let cfg = cfg(12, 3, 13);
    // run 7 steps on a device-resident trainer, checkpoint mid-run
    let mut t1 = synth.trainer(strategy(), cfg.clone()).unwrap();
    for _ in 0..7 {
        t1.train_step().unwrap();
    }
    let ck = t1.capture_checkpoint().unwrap();
    assert_eq!(ck.step, 7);

    // restore into a fresh trainer (fresh runtime, fresh device state)
    let mut t2 = synth.trainer(strategy(), cfg.clone()).unwrap();
    t2.restore_checkpoint(&ck).unwrap();

    // host reference primed with the same restored state: fresh data
    // stream and refresh RNG, exactly like a restored trainer
    let mut host = HostLoop::new(&synth, cfg.clone());
    ck.restore(&mut host.store, &mut host.opt).unwrap();
    host.step = ck.step;

    for s in 7..cfg.steps {
        let a = t2.train_step().unwrap();
        let b = host.step();
        assert_eq!(a, b, "post-restore loss diverged at step {s}");
    }
    assert_state_matches(&mut t2, &host, "restore");
}

#[test]
fn steady_state_steps_stream_only_batch_and_loss() {
    let synth = Synthetic::tiny();
    // refresh only at step 0 → steps 1.. are pure steady state
    let mut trainer = synth.trainer(strategy(), cfg(50, 1000, 3)).unwrap();
    let traffic = trainer.traffic().unwrap();
    trainer.train_step().unwrap(); // step 0: refresh + mask upload
    let before = trainer.runtime.transfer_stats();
    let n = 5;
    for _ in 0..n {
        trainer.train_step().unwrap();
    }
    let d = trainer.runtime.transfer_stats().since(&before);
    // exactly: batch (x, y) + 4 scalars up, loss down — per step
    assert_eq!(d.h2d_bytes, n * traffic.step_h2d_bytes, "h2d bytes/step");
    assert_eq!(d.h2d_calls, n * 6, "uploads/step: x, y, 4 scalars");
    assert_eq!(d.d2h_bytes, n * traffic.step_d2h_bytes, "only the loss comes back");
    assert_eq!(d.d2h_calls, n, "one download per step");
    // and the streamed bytes are decoupled from the dense model size
    assert!(traffic.step_h2d_bytes + traffic.step_d2h_bytes < traffic.resident_bytes);
}

#[test]
fn host_syncs_happen_only_at_protocol_points() {
    let synth = Synthetic::tiny();
    let mut trainer = synth.trainer(strategy(), cfg(50, 4, 3)).unwrap();
    let traffic = trainer.traffic().unwrap();
    trainer.train_step().unwrap(); // step 0 (refresh, host still fresh)
    for _ in 0..3 {
        trainer.train_step().unwrap(); // steps 1..3: steady state
    }
    // step 4 is a refresh: the active θ (installed fwd∪bwd values)
    // comes down once — O(nnz) — and only the index *deltas* go up.
    // Clone the installed masks first so the expected delta can be
    // computed independently of the runtime's own bookkeeping.
    let installed: Vec<_> = trainer
        .store
        .entries
        .iter()
        .filter_map(|e| e.masks.as_ref().map(|m| (m.fwd().clone(), m.bwd().clone())))
        .collect();
    let before = trainer.runtime.transfer_stats();
    trainer.train_step().unwrap();
    let d = trainer.runtime.transfer_stats().since(&before);
    let delta_indices: u64 = trainer
        .store
        .entries
        .iter()
        .filter_map(|e| e.masks.as_ref())
        .zip(&installed)
        .map(|(m, (old_f, old_b))| {
            (old_f.delta_to(m.fwd()).total() + old_b.delta_to(m.bwd()).total()) as u64
        })
        .sum();
    assert_eq!(
        d.d2h_bytes,
        traffic.refresh_d2h_bytes + traffic.step_d2h_bytes,
        "refresh step downloads the active θ only (slots stay resident), plus the loss"
    );
    assert_eq!(
        d.h2d_bytes,
        traffic.refresh_h2d_delta_bytes(delta_indices) + traffic.step_h2d_bytes,
        "refresh step uploads the mask deltas, plus the batch"
    );
    assert!(
        traffic.refresh_d2h_bytes < traffic.legacy_refresh_d2h_bytes,
        "sparse refresh download beats the dense θ sync it replaced"
    );

    // eval streams batches and downloads two scalars per batch — the
    // resident params/masks are reused, nothing else moves
    let before = trainer.runtime.transfer_stats();
    trainer.evaluate().unwrap();
    let d = trainer.runtime.transfer_stats().since(&before);
    let eval_batches = 4u64; // synthetic eval stream length
    assert_eq!(d.h2d_calls, eval_batches * 2, "x and y per eval batch");
    assert_eq!(d.d2h_bytes, eval_batches * 8, "loss+metric scalars only");

    // checkpoint capture is a full device→host sync — θ plus the
    // optimiser slots a refresh leaves resident (once; a second
    // capture without training in between is free)
    let before = trainer.runtime.transfer_stats();
    trainer.capture_checkpoint().unwrap();
    let d = trainer.runtime.transfer_stats().since(&before);
    assert_eq!(d.d2h_bytes, traffic.checkpoint_d2h_bytes);
    assert!(traffic.checkpoint_d2h_bytes > traffic.refresh_d2h_bytes);
    let before = trainer.runtime.transfer_stats();
    trainer.capture_checkpoint().unwrap();
    assert_eq!(
        trainer.runtime.transfer_stats().since(&before).d2h_bytes,
        0,
        "host already synced — no second download"
    );
}

#[test]
fn legacy_traffic_baseline_dwarfs_resident_steady_state() {
    for synth in [Synthetic::tiny(), Synthetic::small()] {
        let trainer = synth.trainer(strategy(), cfg(1, 1, 0)).unwrap();
        let t = trainer.traffic().unwrap();
        assert!(
            t.legacy_step_bytes > 3 * (t.step_h2d_bytes + t.step_d2h_bytes),
            "{}: legacy {} vs streamed {}",
            synth.model.name,
            t.legacy_step_bytes,
            t.step_h2d_bytes + t.step_d2h_bytes
        );
        // amortised traffic at N=100 is within 2x of the streaming floor
        let floor = (t.step_h2d_bytes + t.step_d2h_bytes) as f64;
        assert!(t.amortized_step_bytes(100) < floor + t.legacy_step_bytes as f64);
    }
}
