#!/usr/bin/env python3
"""Writes checkpoint_v2_sparse.ckpt, the pinned TKC2 compatibility fixture.

The byte layout mirrors what `Checkpoint::save` emits: 4-byte magic
"TKC2", u64 LE header length, compact JSON header, little-endian blob.
All f32 values are exactly representable so the rust test can compare
bit-for-bit. The sparse param's touched set ({0,1,2,3,7}) is a superset
of both masks, matching the training invariant; untouched positions are
reconstructed at load time by replaying init seed 31.

Run from the repo root:  python3 rust/tests/fixtures/gen_checkpoint_v2_sparse.py
"""
import json
import struct
from pathlib import Path

blob = bytearray()
sections = []


def section(kind, name, dtype, values, domain=None):
    fmt = "<%d%s" % (len(values), "I" if dtype == "u32" else "f")
    entry = {
        "kind": kind,
        "name": name,
        "dtype": dtype,
        "offset": len(blob),
        "len": len(values),
    }
    if domain is not None:
        entry["domain"] = domain
    sections.append(entry)
    blob.extend(struct.pack(fmt, *values))


# params: w stored sparsely at its touched set, b stored dense
section("param_idx", "w", "u32", [0, 1, 2, 3, 7], domain=8)
section("param_vals", "w", "f32", [0.5, -1.25, 2.0, -0.125, -7.75])
section("param", "b", "f32", [1.0, -2.0, 0.5, 4.0])
# masks of w (fwd ⊆ bwd ⊆ touched)
section("mask_fwd", "w", "u32", [0, 2, 7], domain=8)
section("mask_bwd", "w", "u32", [0, 1, 2, 7], domain=8)
# one optimiser slot per param: sparse for w (aligned to touched),
# dense for b
section("opt_vals", "slot0", "f32", [0.25, 0.125, -0.5, 0.0625, 8.0], domain=8)
section("opt", "slot1", "f32", [0.0625, 0.0, -1.0, 2.5])

header = json.dumps(
    {
        "version": 2,
        "step": 4242,
        "blob_len": len(blob),
        "sections": sections,
        "seed": "31",
    },
    separators=(",", ":"),
)

out = Path(__file__).parent / "checkpoint_v2_sparse.ckpt"
with open(out, "wb") as f:
    f.write(b"TKC2")
    f.write(struct.pack("<Q", len(header)))
    f.write(header.encode())
    f.write(blob)
print(f"wrote {out}: header {len(header)} bytes, blob {len(blob)} bytes")
