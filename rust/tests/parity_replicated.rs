//! Replica-parity + determinism suite for data-parallel training on
//! the host-sim backend (`runtime::replicated`).
//!
//! The pinned invariants:
//!
//! * **Bitwise parity** — N ∈ {1, 2, 3, 4} replicas produce
//!   *bit-identical* losses, params, masks and optimiser state to the
//!   single-device baseline over ≥3 mask-refresh cycles, including
//!   through a mid-run checkpoint save/restore (and a single-device
//!   checkpoint restores into a replicated run). Non-pow2 counts and
//!   non-divisible batches (24 across 3, 10 across 4) are pinned cases.
//! * **Exact per-replica traffic** — the "batch up, loss down"
//!   steady-state invariant of `parity_device_state.rs`, extended per
//!   replica: each device streams exactly its tree-aligned batch shard
//!   + the step scalars up, the loss comes down from replica 0 only,
//!   and the sparse all-reduce moves exactly 4·Σ|bwd| + scalar bytes
//!   per device per step — never 4·numel.
//! * **Fixed-order all-reduce** — canonical-order pairwise reduction:
//!   invariant to replica completion order, exact under f32 fixed-order
//!   semantics, bitwise-equal between the sparse and dense exchange for
//!   every bwd set (empty and full included), and tree-aligned batch
//!   sharding covers every example exactly once for arbitrary
//!   batch/replica combinations while composing with the reduction
//!   tree.
//!
//! CI runs this suite under a `REPLICAS` env matrix (1, 2, 3, 4);
//! without the variable every replica count is exercised in one
//! process.

use topkast::coordinator::{Trainer, TrainerConfig};
use topkast::runtime::{shard_ranges, Optimizer, Synthetic};
use topkast::sparsity::TopKast;
use topkast::tensor::SparseSet;
use topkast::util::proptest::{ensure, property_cases};
use topkast::xla::PjRtClient;

fn cfg(steps: usize, refresh_every: usize, seed: u64, replicas: usize) -> TrainerConfig {
    TrainerConfig { steps, refresh_every, seed, replicas, ..TrainerConfig::default() }
}

fn strategy() -> Box<TopKast> {
    Box::new(TopKast::from_sparsities(0.8, 0.5))
}

/// Replica counts to exercise: the `REPLICAS` env var pins one (the CI
/// matrix); otherwise all of {1, 2, 3, 4} run in-process.
fn replicas_under_test() -> Vec<usize> {
    match std::env::var("REPLICAS") {
        Ok(v) => vec![v
            .parse()
            .unwrap_or_else(|_| panic!("REPLICAS must be an integer, got {v:?}"))],
        Err(_) => vec![1, 2, 3, 4],
    }
}

fn multi_replicas() -> Vec<usize> {
    replicas_under_test().into_iter().filter(|&r| r > 1).collect()
}

/// Bitwise comparison of two trainers' full host-visible state.
fn assert_trainers_match(a: &mut Trainer, b: &mut Trainer, tag: &str) {
    a.sync_host().unwrap();
    b.sync_host().unwrap();
    for (ea, eb) in a.store.entries.iter().zip(&b.store.entries) {
        assert_eq!(ea.values, eb.values, "{tag}: params diverged on {}", ea.spec.name);
        match (&ea.masks, &eb.masks) {
            (Some(ma), Some(mb)) => {
                assert_eq!(ma.fwd(), mb.fwd(), "{tag}: fwd mask {}", ea.spec.name);
                assert_eq!(ma.bwd(), mb.bwd(), "{tag}: bwd mask {}", ea.spec.name);
            }
            (None, None) => {}
            _ => panic!("{tag}: mask presence mismatch"),
        }
    }
    assert_eq!(a.opt_slots(), b.opt_slots(), "{tag}: optimiser state");
}

/// Per-replica steady-state h2d bytes: each replica streams its own
/// tree-aligned batch shard (x, y) plus the step scalars. Shards are
/// *unequal* for non-pow2 replica counts, so this is a vector — index
/// r is replica r's link.
fn per_replica_step_h2d(trainer: &Trainer) -> Vec<u64> {
    let rep = trainer.model.replication.as_ref().unwrap();
    let layout = trainer.model.replicated_layout(rep.replicas).unwrap();
    let scalar_bytes = 4 * layout.per_replica.scalars.len() as u64;
    rep.grads
        .iter()
        .map(|g| {
            let shard: u64 = g.inputs[g.inputs.len() - 2..]
                .iter()
                .map(|io| 4 * io.shape.numel() as u64)
                .sum();
            shard + scalar_bytes
        })
        .collect()
}

#[test]
fn replicated_matches_single_device_bitwise_over_refresh_cycles() {
    for synth in [Synthetic::tiny(), Synthetic::small()] {
        for replicas in replicas_under_test() {
            // 11 steps / refresh every 3 → refreshes at 0, 3, 6, 9
            // (≥3 full cycles)
            let steps = 11;
            let mut baseline = synth.trainer(strategy(), cfg(steps, 3, 5, 1)).unwrap();
            let mut replicated =
                synth.trainer(strategy(), cfg(steps, 3, 5, replicas)).unwrap();
            assert_eq!(replicated.replica_count(), replicas);
            for s in 0..steps {
                let a = baseline.train_step().unwrap();
                let b = replicated.train_step().unwrap();
                assert_eq!(
                    a, b,
                    "{} x{replicas}: loss diverged at step {s}",
                    synth.model.name
                );
            }
            replicated.verify_replica_lockstep().unwrap();
            let tag = format!("{} x{replicas}", synth.model.name);
            assert_trainers_match(&mut replicated, &mut baseline, &tag);
            // eval reads replica 0's resident buffers — same bits, same
            // result
            let ea = baseline.evaluate().unwrap();
            let eb = replicated.evaluate().unwrap();
            assert_eq!(ea.loss_mean, eb.loss_mean, "{tag}: eval loss");
        }
    }
}

/// The pinned elasticity cases from the sparse-exchange PR: batch 24
/// across 3 replicas (non-pow2, tree-aligned shards 6+6+12) and batch
/// 10 across 4 (remainder shards 3+2+3+2) both train bit-identically
/// to the single-device baseline across refresh cycles.
#[test]
fn non_pow2_and_remainder_batches_match_single_device_bitwise() {
    let cases = [
        (Synthetic::new("syn_b24", 8, 16, 24, Optimizer::Sgd), 3usize),
        (Synthetic::new("syn_b10", 8, 16, 10, Optimizer::Adam), 4usize),
    ];
    for (synth, replicas) in cases {
        let steps = 11; // refresh every 3 → refreshes at 0, 3, 6, 9
        let mut baseline = synth.trainer(strategy(), cfg(steps, 3, 17, 1)).unwrap();
        let mut replicated =
            synth.trainer(strategy(), cfg(steps, 3, 17, replicas)).unwrap();
        assert_eq!(replicated.replica_count(), replicas);
        for s in 0..steps {
            let a = baseline.train_step().unwrap();
            let b = replicated.train_step().unwrap();
            assert_eq!(
                a, b,
                "{} x{replicas}: loss diverged at step {s}",
                synth.model.name
            );
        }
        replicated.verify_replica_lockstep().unwrap();
        let tag = format!("{} x{replicas}", synth.model.name);
        assert_trainers_match(&mut replicated, &mut baseline, &tag);
    }
}

#[test]
fn parity_survives_checkpoint_restore_mid_run() {
    let synth = Synthetic::tiny();
    for replicas in replicas_under_test() {
        let total = 12;
        // run 7 steps on both paths; the mid-run checkpoints must agree
        let mut base1 = synth.trainer(strategy(), cfg(total, 3, 13, 1)).unwrap();
        let mut repl1 = synth.trainer(strategy(), cfg(total, 3, 13, replicas)).unwrap();
        for _ in 0..7 {
            let a = base1.train_step().unwrap();
            let b = repl1.train_step().unwrap();
            assert_eq!(a, b, "x{replicas}: pre-checkpoint loss diverged");
        }
        let ck_base = base1.capture_checkpoint().unwrap();
        let ck_repl = repl1.capture_checkpoint().unwrap();
        assert_eq!(ck_base.step, 7);
        assert_eq!(ck_repl.step, 7);
        assert_eq!(ck_base.params, ck_repl.params, "x{replicas}: checkpoint params");
        assert_eq!(ck_base.masks_fwd, ck_repl.masks_fwd);
        assert_eq!(ck_base.masks_bwd, ck_repl.masks_bwd);
        assert_eq!(ck_base.opt, ck_repl.opt, "x{replicas}: checkpoint opt");

        // cross-restore: the *single-device* checkpoint resumes a
        // replicated run (fresh runtime, fresh device set), against a
        // restored single-device reference
        let mut base2 = synth.trainer(strategy(), cfg(total, 3, 13, 1)).unwrap();
        base2.restore_checkpoint(&ck_base).unwrap();
        let mut repl2 = synth.trainer(strategy(), cfg(total, 3, 13, replicas)).unwrap();
        repl2.restore_checkpoint(&ck_base).unwrap();
        for s in 7..total {
            let a = base2.train_step().unwrap();
            let b = repl2.train_step().unwrap();
            assert_eq!(a, b, "x{replicas}: post-restore loss diverged at step {s}");
        }
        repl2.verify_replica_lockstep().unwrap();
        assert_trainers_match(&mut repl2, &mut base2, &format!("restore x{replicas}"));
    }
}

#[test]
fn steady_state_per_replica_traffic_is_exact() {
    let synth = Synthetic::tiny();
    for replicas in multi_replicas() {
        // refresh only at step 0 → steps 1.. are pure steady state
        let mut trainer =
            synth.trainer(strategy(), cfg(40, 1000, 3, replicas)).unwrap();
        let traffic = trainer.traffic().unwrap();
        assert_eq!(traffic.replicas, replicas as u64);
        // the gradient exchange runs sparse: the step account IS the
        // sparse account, and at bwd density 0.5 it beats the dense
        // plane it replaced
        assert_eq!(traffic.allreduce_step_bytes, traffic.allreduce_sparse_bytes);
        assert!(
            traffic.allreduce_sparse_bytes < traffic.legacy_allreduce_bytes,
            "O(nnz) exchange must undercut the dense all-reduce"
        );
        let shard_h2d = per_replica_step_h2d(&trainer);
        assert_eq!(shard_h2d[0], traffic.replica_step_h2d_bytes);
        assert_eq!(
            traffic.step_h2d_bytes,
            shard_h2d.iter().sum::<u64>(),
            "aggregate = Σ per-replica shards (unequal for non-pow2 counts)"
        );
        let rep = trainer.model.replication.as_ref().unwrap();
        let payload_tensors = rep.grads[0].outputs.len() as u64;
        let layout = trainer.model.replicated_layout(replicas).unwrap();
        let uploads_per_step = (layout.per_replica.batch.len()
            + layout.per_replica.scalars.len()) as u64;

        trainer.train_step().unwrap(); // step 0: refresh + mask upload
        let before: Vec<_> = (0..replicas)
            .map(|r| trainer.runtime.device_transfer_stats(r).unwrap())
            .collect();
        let n = 5u64;
        for _ in 0..n {
            trainer.train_step().unwrap();
        }
        for r in 0..replicas {
            let d = trainer
                .runtime
                .device_transfer_stats(r)
                .unwrap()
                .since(&before[r]);
            // batch shard + step scalars up, per replica
            assert_eq!(
                d.h2d_bytes,
                n * shard_h2d[r],
                "replica {r}: h2d bytes/step (its own shard + scalars)"
            );
            assert_eq!(
                d.h2d_calls,
                n * uploads_per_step,
                "replica {r}: uploads/step (shard x, shard y, scalars)"
            );
            // the all-reduce payload crosses the interconnect once per
            // payload tensor per step, on every device
            assert_eq!(
                d.ar_bytes,
                n * traffic.allreduce_step_bytes / replicas as u64,
                "replica {r}: all-reduce bytes/step"
            );
            assert_eq!(d.ar_calls, n * payload_tensors, "replica {r}: ar calls");
            // only replica 0 talks back to the host (the loss scalar)
            if r == 0 {
                assert_eq!(d.d2h_bytes, n * traffic.step_d2h_bytes, "loss down");
                assert_eq!(d.d2h_calls, n);
            } else {
                assert_eq!(d.d2h_bytes, 0, "replica {r}: no downloads");
                assert_eq!(d.d2h_calls, 0);
            }
        }
        // aggregate view matches the model too ("batch up, loss down")
        let total: topkast::xla::TransferSnapshot = {
            let mut agg = topkast::xla::TransferSnapshot::default();
            for (r, earlier) in before.iter().enumerate() {
                let now = trainer.runtime.device_transfer_stats(r).unwrap();
                agg.accumulate(&now.since(earlier));
            }
            agg
        };
        assert_eq!(total.h2d_bytes, n * traffic.step_h2d_bytes);
        assert_eq!(total.d2h_bytes, n * traffic.step_d2h_bytes);
        assert_eq!(total.ar_bytes, n * traffic.allreduce_step_bytes);
        // lockstep still holds (this downloads state, so it comes last)
        trainer.verify_replica_lockstep().unwrap();
    }
}

#[test]
fn refresh_broadcasts_masks_to_every_replica() {
    let synth = Synthetic::tiny();
    for replicas in multi_replicas() {
        let mut trainer = synth.trainer(strategy(), cfg(10, 4, 3, replicas)).unwrap();
        let traffic = trainer.traffic().unwrap();
        let shard_h2d = per_replica_step_h2d(&trainer);
        for _ in 0..4 {
            trainer.train_step().unwrap(); // step 0 refresh + 3 steady
        }
        // step 4 is a refresh: the active θ comes down from replica 0
        // once (O(nnz)); the index *deltas* broadcast to every replica
        // (O(Δnnz) per link). Clone the installed masks first so the
        // expected delta is computed independently.
        let installed: Vec<_> = trainer
            .store
            .entries
            .iter()
            .filter_map(|e| e.masks.as_ref().map(|m| (m.fwd().clone(), m.bwd().clone())))
            .collect();
        let before: Vec<_> = (0..replicas)
            .map(|r| trainer.runtime.device_transfer_stats(r).unwrap())
            .collect();
        trainer.train_step().unwrap();
        let delta_indices: u64 = trainer
            .store
            .entries
            .iter()
            .filter_map(|e| e.masks.as_ref())
            .zip(&installed)
            .map(|(m, (old_f, old_b))| {
                (old_f.delta_to(m.fwd()).total() + old_b.delta_to(m.bwd()).total())
                    as u64
            })
            .sum();
        let per_replica_mask_bytes = 4 * delta_indices;
        assert_eq!(
            traffic.refresh_h2d_delta_bytes(delta_indices),
            replicas as u64 * per_replica_mask_bytes,
            "mask-pure strategy: the delta broadcast is the whole refresh upload"
        );
        for r in 0..replicas {
            let d = trainer
                .runtime
                .device_transfer_stats(r)
                .unwrap()
                .since(&before[r]);
            assert_eq!(
                d.h2d_bytes,
                per_replica_mask_bytes + shard_h2d[r],
                "replica {r}: refresh uploads its delta copy + its step shard"
            );
            if r == 0 {
                assert_eq!(
                    d.d2h_bytes,
                    traffic.refresh_d2h_bytes + traffic.step_d2h_bytes,
                    "refresh syncs the active θ from the host-facing replica only"
                );
            } else {
                assert_eq!(d.d2h_bytes, 0, "replica {r}: refresh costs no download");
            }
        }
        // the single host decision reached every device: still lockstep
        trainer.verify_replica_lockstep().unwrap();
    }
}

// ---------------------------------------------------------------------------
// property tests: the fixed-order all-reduce primitive + batch sharding
// ---------------------------------------------------------------------------

/// Host-side reference of the canonical pairwise tree the sim uses.
fn reference_tree(vals: &[Vec<f32>], j: usize) -> f32 {
    fn go(vals: &[Vec<f32>], j: usize) -> f32 {
        match vals.len() {
            1 => vals[0][j],
            n => {
                let m = n.div_ceil(2);
                go(&vals[..m], j) + go(&vals[m..], j)
            }
        }
    }
    go(vals, j)
}

#[test]
fn property_all_reduce_is_canonical_order_and_exact() {
    property_cases("all-reduce: fixed order, exact f32 tree sum", 96, |rng| {
        let replicas = 1 + rng.next_below(6) as usize;
        let len = 1 + rng.next_below(32) as usize;
        let vals: Vec<Vec<f32>> = (0..replicas)
            .map(|_| (0..len).map(|_| rng.normal_f32(2.0)).collect())
            .collect();
        let client = PjRtClient::cpu_with_devices(replicas).map_err(|e| e.to_string())?;
        // "completion order" = the order partials were produced; upload
        // in a rotated order, reduce in canonical order
        let rotate = rng.next_below(replicas as u64) as usize;
        let mut bufs = vec![None; replicas];
        for i in 0..replicas {
            let r = (i + rotate) % replicas;
            bufs[r] = Some(
                client
                    .buffer_from_host_buffer::<f32>(&vals[r], &[len], Some(r))
                    .map_err(|e| e.to_string())?,
            );
        }
        let bufs: Vec<_> = bufs.into_iter().map(|b| b.unwrap()).collect();
        let refs: Vec<_> = bufs.iter().collect();
        let reduced = client.all_reduce_sum(&refs).map_err(|e| e.to_string())?;
        ensure(reduced.len() == replicas, "one result per replica")?;
        let want: Vec<f32> = (0..len).map(|j| reference_tree(&vals, j)).collect();
        for (r, buf) in reduced.iter().enumerate() {
            let got = buf
                .to_literal_sync()
                .and_then(|l| l.to_vec::<f32>())
                .map_err(|e| e.to_string())?;
            // bitwise: exact fixed-order f32 semantics, not approximate
            ensure(
                got.iter().map(|v| v.to_bits()).eq(want.iter().map(|v| v.to_bits())),
                format!("replica {r}: tree sum mismatch"),
            )?;
        }
        Ok(())
    });
}

#[test]
fn property_all_reduce_invariant_to_completion_order() {
    property_cases("all-reduce: completion order irrelevant", 64, |rng| {
        let replicas = 2 + rng.next_below(4) as usize;
        let len = 1 + rng.next_below(16) as usize;
        let vals: Vec<Vec<f32>> = (0..replicas)
            .map(|_| (0..len).map(|_| rng.normal_f32(1.0)).collect())
            .collect();
        let run = |order: Vec<usize>| -> Result<Vec<u32>, String> {
            let client =
                PjRtClient::cpu_with_devices(replicas).map_err(|e| e.to_string())?;
            let mut bufs = vec![None; replicas];
            for &r in &order {
                bufs[r] = Some(
                    client
                        .buffer_from_host_buffer::<f32>(&vals[r], &[len], Some(r))
                        .map_err(|e| e.to_string())?,
                );
            }
            let bufs: Vec<_> = bufs.into_iter().map(|b| b.unwrap()).collect();
            let refs: Vec<_> = bufs.iter().collect();
            let out = client.all_reduce_sum(&refs).map_err(|e| e.to_string())?;
            out[0]
                .to_literal_sync()
                .and_then(|l| l.to_vec::<f32>())
                .map(|v| v.iter().map(|x| x.to_bits()).collect())
                .map_err(|e| e.to_string())
        };
        let forward: Vec<usize> = (0..replicas).collect();
        let mut shuffled = forward.clone();
        // Fisher–Yates with the property rng
        for i in (1..shuffled.len()).rev() {
            let j = rng.next_below(i as u64 + 1) as usize;
            shuffled.swap(i, j);
        }
        ensure(
            run(forward)? == run(shuffled)?,
            "result depends on completion order",
        )
    });
}

#[test]
fn property_sharding_covers_every_example_exactly_once() {
    property_cases("shard_ranges: exact cover, tree-aligned", 256, |rng| {
        let n = rng.next_below(201) as usize;
        let replicas = 1 + rng.next_below(16) as usize;
        let shards = shard_ranges(n, replicas);
        ensure(shards.len() == replicas, "one shard per replica")?;
        // contiguous exact cover: starts chain, ends at n
        let mut expect_start = 0;
        for (r, s) in shards.iter().enumerate() {
            ensure(
                s.start == expect_start,
                format!("shard {r} starts at {} not {expect_start}", s.start),
            )?;
            ensure(s.end >= s.start, "non-negative shard")?;
            expect_start = s.end;
        }
        ensure(expect_start == n, "shards must cover 0..n exactly")?;
        // tree alignment: the split law is the reduction tree's own.
        // The left ⌈R/2⌉ replicas shard the first ⌈n/2⌉ examples as a
        // self-similar sub-tree; the right ⌊R/2⌋ shard the rest. This
        // is what makes shard partials compose bitwise under the
        // canonical pairwise all-reduce — NOT size balance (24 across
        // 3 shards as 6+6+12 on purpose).
        if replicas >= 2 {
            let rl = replicas.div_ceil(2);
            let mid = n.div_ceil(2);
            let left = shard_ranges(mid, rl);
            ensure(shards[..rl] == left[..], "left half is its own sub-tree")?;
            let right = shard_ranges(n - mid, replicas - rl);
            for (s, t) in shards[rl..].iter().zip(&right) {
                ensure(
                    s.start == t.start + mid && s.end == t.end + mid,
                    "right half is its own sub-tree, shifted by ⌈n/2⌉",
                )?;
            }
        }
        // elastic floor: whenever there is at least one example per
        // replica, every replica gets work
        if n >= replicas {
            ensure(
                shards.iter().all(|s| s.end > s.start),
                format!("empty shard with n={n} ≥ replicas={replicas}"),
            )?;
        }
        Ok(())
    });
}

/// The sparse exchange stated directly at the primitive: for any bwd
/// set — empty, full, or random — `all_reduce_sum_sparse` over
/// payloads that are exactly +0.0 off-set is bitwise-identical to the
/// dense all-reduce it replaces, on every replica, for N ∈ {2, 3, 4}.
#[test]
fn property_sparse_all_reduce_matches_dense_bitwise() {
    property_cases("sparse all-reduce ≡ dense all-reduce", 96, |rng| {
        let replicas = 2 + rng.next_below(3) as usize; // {2, 3, 4}
        let n = 1 + rng.next_below(48) as usize;
        let set = match rng.next_below(8) {
            0 => SparseSet::empty(n),
            1 => SparseSet::full(n),
            _ => {
                let idx: Vec<u32> =
                    (0..n as u32).filter(|_| rng.next_below(2) == 1).collect();
                SparseSet::from_sorted(n, idx).map_err(|e| e.to_string())?
            }
        };
        // bwd-masked gradients are exactly +0.0 off-set (the `select`
        // contract) — build the payloads the same way
        let vals: Vec<Vec<f32>> = (0..replicas)
            .map(|_| {
                let mut v = vec![0.0f32; n];
                for &j in set.indices() {
                    v[j as usize] = rng.normal_f32(2.0);
                }
                v
            })
            .collect();
        let client =
            PjRtClient::cpu_with_devices(replicas).map_err(|e| e.to_string())?;
        let bufs = (0..replicas)
            .map(|r| client.buffer_from_host_buffer::<f32>(&vals[r], &[n], Some(r)))
            .collect::<Result<Vec<_>, _>>()
            .map_err(|e| e.to_string())?;
        let refs: Vec<_> = bufs.iter().collect();
        let dense = client.all_reduce_sum(&refs).map_err(|e| e.to_string())?;
        let sparse = client
            .all_reduce_sum_sparse(&refs, &set)
            .map_err(|e| e.to_string())?;
        ensure(sparse.len() == replicas, "one result per replica")?;
        for (r, (d, s)) in dense.iter().zip(&sparse).enumerate() {
            let dv = d
                .to_literal_sync()
                .and_then(|l| l.to_vec::<f32>())
                .map_err(|e| e.to_string())?;
            let sv = s
                .to_literal_sync()
                .and_then(|l| l.to_vec::<f32>())
                .map_err(|e| e.to_string())?;
            ensure(
                dv.iter().map(|v| v.to_bits()).eq(sv.iter().map(|v| v.to_bits())),
                format!("replica {r}: sparse exchange diverged from dense"),
            )?;
        }
        Ok(())
    });
}

/// The exactness theorem the replicated trainer rests on, stated
/// directly: for *any* batch size and replica count, the full-batch
/// reduction equals the canonical all-reduce of tree-aligned shard
/// partials, bit for bit.
#[test]
fn property_shard_partials_compose_bitwise() {
    property_cases("pairwise composition over tree-aligned shards", 96, |rng| {
        let n = 1 + rng.next_below(64) as usize;
        let replicas = 1 + rng.next_below(n.min(6) as u64) as usize;
        let vals: Vec<f32> = (0..n).map(|_| rng.normal_f32(3.0)).collect();
        let client =
            PjRtClient::cpu_with_devices(replicas).map_err(|e| e.to_string())?;
        let sum_on = |v: &[f32], device: usize| -> Result<topkast::xla::PjRtBuffer, String> {
            let b = topkast::xla::XlaBuilder::new("sum");
            let shape = topkast::xla::Shape::array::<f32>(vec![v.len()]);
            let x = b.parameter_s(0, &shape, "x").map_err(|e| e.to_string())?;
            let comp = b
                .tuple(&[x.reduce_sum().map_err(|e| e.to_string())?])
                .and_then(|t| t.build())
                .map_err(|e| e.to_string())?;
            let exe = client.compile(&comp).map_err(|e| e.to_string())?;
            let buf = client
                .buffer_from_host_buffer::<f32>(v, &[v.len()], Some(device))
                .map_err(|e| e.to_string())?;
            Ok(exe.execute_b(&[&buf]).map_err(|e| e.to_string())?[0][0]
                .tuple_parts()
                .map_err(|e| e.to_string())?[0]
                .clone())
        };
        let full = sum_on(&vals, 0)?
            .to_literal_sync()
            .and_then(|l| l.to_vec::<f32>())
            .map_err(|e| e.to_string())?;
        let shards = shard_ranges(n, replicas);
        let partials = shards
            .iter()
            .enumerate()
            .map(|(r, s)| sum_on(&vals[s.clone()], r))
            .collect::<Result<Vec<_>, _>>()?;
        let refs: Vec<_> = partials.iter().collect();
        let reduced = client.all_reduce_sum(&refs).map_err(|e| e.to_string())?;
        let got = reduced[0]
            .to_literal_sync()
            .and_then(|l| l.to_vec::<f32>())
            .map_err(|e| e.to_string())?;
        ensure(
            got[0].to_bits() == full[0].to_bits(),
            format!(
                "composition broke: shards({replicas}) gave {} vs full {}",
                got[0], full[0]
            ),
        )
    });
}
