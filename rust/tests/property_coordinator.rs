//! Property tests over the coordinator's host-side invariants: routing
//! of mask updates through strategies, density bookkeeping, FLOPs-model
//! monotonicity, store state management. No PJRT involved — these are
//! fast and run hundreds of random cases each.

use std::collections::BTreeMap;

use topkast::runtime::manifest::{InitKind, ParamSpec};
use topkast::sparsity::{
    strategy_from_str, update_store_masks, Dense, MagnitudePruning, ParamStore,
    RigL, SetEvolve, StaticRandom, TopKast, TopKastRandom,
};
use topkast::sparsity::flops;
use topkast::tensor::Shape;
use topkast::util::proptest::{ensure, property, property_cases};
use topkast::util::rng::Pcg64;

fn rand_specs(rng: &mut Pcg64) -> Vec<ParamSpec> {
    let n_tensors = 1 + rng.next_below(5) as usize;
    (0..n_tensors)
        .map(|i| {
            let rows = 2 + rng.next_below(20) as usize;
            let cols = 2 + rng.next_below(20) as usize;
            ParamSpec {
                name: format!("t{i}"),
                shape: Shape::new(&[rows, cols]),
                init: InitKind::Normal,
                init_scale: 0.1,
                sparse: rng.next_f64() < 0.8,
                mac: (rows * cols) as u64,
            }
        })
        .collect()
}

#[test]
fn prop_store_mask_update_preserves_invariants_for_all_strategies() {
    property_cases("all strategies keep store invariants", 64, |rng| {
        let specs = rand_specs(rng);
        let mut store = ParamStore::init(&specs, rng.next_u64());
        let d = 0.05 + rng.next_f64() * 0.6;
        let m = rng.next_f64() * (1.0 - d);
        let strategies: Vec<Box<dyn topkast::sparsity::MaskStrategy>> = vec![
            Box::new(TopKast::new(d, d + m)),
            Box::new(TopKastRandom::new(d, d + m)),
            Box::new(StaticRandom::new(d)),
            Box::new(SetEvolve::new(d, 0.3, 0.05)),
            Box::new(MagnitudePruning::new(d)),
            Box::new(Dense),
        ];
        for mut s in strategies {
            let mut r2 = rng.fork(7);
            // two refreshes at different steps
            for step in [0usize, 50] {
                update_store_masks(s.as_mut(), &mut store, None, &mut r2, step, 100)
                    .map_err(|e| e.to_string())?;
                for e in &store.entries {
                    match (&e.masks, e.spec.sparse) {
                        (Some(masks), true) => {
                            ensure(
                                masks.is_nested(),
                                format!("{}: A ⊄ B under {}", e.spec.name, s.name()),
                            )?;
                            // index sets must stay canonical over the
                            // tensor's domain, and the dense view must
                            // agree with the set sizes
                            ensure(
                                masks.domain() == e.values.len(),
                                "mask domain drifted from the tensor size",
                            )?;
                            ensure(
                                masks.fwd().indices().windows(2).all(|w| w[0] < w[1]),
                                "fwd indices not strictly increasing",
                            )?;
                            ensure(
                                masks.fwd_nnz()
                                    == masks
                                        .fwd_dense()
                                        .iter()
                                        .filter(|&&x| x == 1.0)
                                        .count(),
                                "set size disagrees with the dense view",
                            )?;
                            ensure(
                                masks.fwd().is_subset_of(masks.touched())
                                    && masks.bwd().is_subset_of(masks.touched()),
                                "installed active sets must be touched",
                            )?;
                        }
                        (None, false) => {}
                        _ => return Err("mask presence mismatch".into()),
                    }
                }
            }
        }
        Ok(())
    });
}

#[test]
fn prop_rigl_density_preserved_with_random_grads() {
    property_cases("rigl drop/grow keeps density", 64, |rng| {
        let specs = rand_specs(rng);
        let mut store = ParamStore::init(&specs, rng.next_u64());
        let d = 0.1 + rng.next_f64() * 0.5;
        let mut rigl = RigL::new(d, 0.3, 10);
        let mut r2 = rng.fork(3);
        update_store_masks(&mut rigl, &mut store, None, &mut r2, 0, 1000)
            .map_err(|e| e.to_string())?;
        // fake dense grads
        let mut grads = BTreeMap::new();
        for e in &store.entries {
            if e.spec.sparse {
                grads.insert(
                    e.spec.name.clone(),
                    (0..e.values.len())
                        .map(|_| r2.next_f32().abs())
                        .collect::<Vec<f32>>(),
                );
            }
        }
        update_store_masks(&mut rigl, &mut store, Some(&grads), &mut r2, 10, 1000)
            .map_err(|e| e.to_string())?;
        for e in &store.entries {
            if let Some(m) = &e.masks {
                let k = topkast::sparsity::topk::k_for_density(e.values.len(), d);
                ensure(
                    m.fwd_nnz() == k,
                    format!("{}: density drifted {} != {k}", e.spec.name, m.fwd_nnz()),
                )?;
            }
        }
        Ok(())
    });
}

#[test]
fn prop_flops_model_monotone_in_densities() {
    property_cases("flops monotone", 128, |rng| {
        let specs = rand_specs(rng);
        let d1 = rng.next_f64() * 0.5;
        let d2 = d1 + rng.next_f64() * (1.0 - d1);
        let b = rng.next_f64();
        ensure(
            flops::step_flops(&specs, d1, b) <= flops::step_flops(&specs, d2, b) + 1e-9,
            "fwd density monotonicity",
        )?;
        ensure(
            flops::step_flops(&specs, b.min(d1), d1)
                <= flops::step_flops(&specs, b.min(d1), d2) + 1e-9,
            "bwd density monotonicity",
        )?;
        ensure(
            flops::inference_flops(&specs, d1) <= flops::inference_flops(&specs, d2) + 1e-9,
            "inference monotonicity",
        )
    });
}

#[test]
fn prop_flops_fraction_bounded_by_one_for_sparse_methods() {
    property_cases("sparse never costs more than dense", 64, |rng| {
        let specs = rand_specs(rng);
        let d = 0.05 + rng.next_f64() * 0.9;
        let m = rng.next_f64() * (1.0 - d);
        let tk = TopKast::new(d, d + m);
        let f = flops::run_flops_fraction(&tk, &specs, 1000, 1.0);
        ensure(
            f <= 1.0 + 1e-9,
            format!("topkast flops fraction {f} > dense at d={d} m={m}"),
        )?;
        let st = StaticRandom::new(d);
        let f = flops::run_flops_fraction(&st, &specs, 1000, 1.0);
        ensure(f <= 1.0 + 1e-9, "static flops above dense")
    });
}

#[test]
fn prop_strategy_parser_roundtrips_densities() {
    property("parser: sparsity args map to densities", |rng| {
        let sf = (rng.next_below(90) as f64) / 100.0;
        let extra = rng.next_below((90 - (sf * 100.0) as u64).max(1)) as f64 / 100.0;
        let sb = (sf - extra).max(0.0);
        let s = strategy_from_str(&format!("topkast:{sf},{sb}"))
            .map_err(|e| e.to_string())?;
        let d = s.densities(0, 100);
        ensure(
            (d.fwd - (1.0 - sf)).abs() < 1e-9,
            format!("fwd density {} for sparsity {sf}", d.fwd),
        )?;
        ensure((d.bwd - (1.0 - sb)).abs() < 1e-9, "bwd density")
    });
}

#[test]
fn prop_store_init_respects_spec_shapes_and_determinism() {
    property_cases("store init", 64, |rng| {
        let specs = rand_specs(rng);
        let seed = rng.next_u64();
        let a = ParamStore::init(&specs, seed);
        let b = ParamStore::init(&specs, seed);
        for (x, y) in a.entries.iter().zip(&b.entries) {
            ensure(x.values == y.values, "same-seed init differs")?;
            ensure(
                x.values.len() == x.spec.shape.numel(),
                "value count != shape numel",
            )?;
        }
        ensure(a.total_params() == specs.iter().map(|s| s.shape.numel()).sum(), "total")
    });
}

#[test]
fn prop_pruning_schedule_monotone_and_bounded() {
    property("pruning density monotone non-increasing", |rng| {
        let d_final = 0.02 + rng.next_f64() * 0.5;
        let p = MagnitudePruning::new(d_final);
        let total = 100 + rng.next_below(10_000) as usize;
        let mut last = f64::INFINITY;
        for i in 0..=20 {
            let step = i * total / 20;
            let d = p.density_at(step, total);
            ensure(d <= last + 1e-12, "density increased")?;
            ensure(
                (d_final - 1e-9..=1.0 + 1e-9).contains(&d),
                format!("density {d} out of [{d_final}, 1]"),
            )?;
            last = d;
        }
        Ok(())
    });
}
