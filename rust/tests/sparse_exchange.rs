//! The compact sparse exchange plane, end to end: O(nnz) refresh
//! downloads and O(Δnnz) mask broadcasts pinned by exact
//! transfer-count assertions at two sparsity levels, v2 checkpoints
//! that shrink with sparsity and survive disk round-trips, and the
//! pinned v1 fixture written by the legacy dense writer.

use topkast::coordinator::{Checkpoint, TensorPayload, Trainer, TrainerConfig};
use topkast::runtime::Synthetic;
use topkast::sparsity::topk::k_for_density;
use topkast::sparsity::{ParamStore, TopKast};
use topkast::tensor::SparseSet;

fn cfg(steps: usize, refresh_every: usize, seed: u64) -> TrainerConfig {
    TrainerConfig { steps, refresh_every, seed, ..TrainerConfig::default() }
}

fn trainer_at(synth: &Synthetic, sparsity: f64, cfg: TrainerConfig) -> Trainer {
    synth
        .trainer(Box::new(TopKast::from_sparsities(sparsity, sparsity)), cfg)
        .unwrap()
}

/// Clone the sparse tensors' current (installed) index sets.
fn mask_sets(trainer: &Trainer) -> Vec<(SparseSet, SparseSet)> {
    trainer
        .store
        .entries
        .iter()
        .filter_map(|e| e.masks.as_ref().map(|m| (m.fwd().clone(), m.bwd().clone())))
        .collect()
}

/// Σ per-tensor |added| + |removed| across both masks, old → current.
fn delta_indices(trainer: &Trainer, old: &[(SparseSet, SparseSet)]) -> u64 {
    trainer
        .store
        .entries
        .iter()
        .filter_map(|e| e.masks.as_ref())
        .zip(old)
        .map(|(m, (of, ob))| {
            (of.delta_to(m.fwd()).total() + ob.delta_to(m.bwd()).total()) as u64
        })
        .sum()
}

/// The acceptance criterion stated directly: at a refresh, d2h moves
/// exactly 4·Σnnz(fwd∪bwd) bytes (+ the loss) and h2d exactly
/// 4·Δindices (+ the step batch) — verified at two sparsity levels,
/// with the byte counts shrinking as sparsity rises.
#[test]
fn refresh_traffic_is_exactly_nnz_down_and_delta_up_at_two_sparsities() {
    let synth = Synthetic::small();
    let mut refresh_d2h_by_sparsity = Vec::new();
    for sparsity in [0.8, 0.98] {
        let mut trainer = trainer_at(&synth, sparsity, cfg(20, 4, 3));
        let traffic = trainer.traffic().unwrap();
        // analytic refresh d2h = 4·Σ k_for_density(n_t, d) — nnz-shaped
        let d = 1.0 - sparsity;
        let want_nnz_bytes: u64 = synth
            .model
            .sparse_params()
            .iter()
            .map(|p| 4 * k_for_density(p.shape.numel(), d) as u64)
            .sum();
        assert_eq!(traffic.refresh_d2h_bytes, want_nnz_bytes);
        for _ in 0..4 {
            trainer.train_step().unwrap(); // step-0 refresh + 3 steady
        }
        // independently recompute the expected Σ|fwd∪bwd| from the
        // installed masks, then meter the step-4 refresh exactly
        let installed = mask_sets(&trainer);
        let union_bytes: u64 = installed
            .iter()
            .map(|(f, b)| 4 * f.union(b).len() as u64)
            .sum();
        assert_eq!(union_bytes, want_nnz_bytes, "A ⊆ B ⇒ union is B");
        let before = trainer.runtime.transfer_stats();
        trainer.train_step().unwrap();
        let moved = trainer.runtime.transfer_stats().since(&before);
        let delta = delta_indices(&trainer, &installed);
        assert_eq!(
            moved.d2h_bytes,
            union_bytes + traffic.step_d2h_bytes,
            "sparsity {sparsity}: refresh downloads the active θ + the loss"
        );
        assert_eq!(
            moved.h2d_bytes,
            4 * delta + traffic.step_h2d_bytes,
            "sparsity {sparsity}: refresh uploads the index deltas + the batch"
        );
        assert_eq!(
            moved.h2d_bytes,
            traffic.refresh_h2d_delta_bytes(delta) + traffic.step_h2d_bytes,
            "the TrafficModel delta account matches the meter"
        );
        // and far below the legacy dense exchange
        assert!(union_bytes < traffic.legacy_refresh_d2h_bytes / 4);
        refresh_d2h_by_sparsity.push(union_bytes);
    }
    assert!(
        refresh_d2h_by_sparsity[1] < refresh_d2h_by_sparsity[0] / 5,
        "98% sparse refresh must move far less than 80% sparse: {refresh_d2h_by_sparsity:?}"
    );
}

/// Checkpoint-size acceptance criterion: a 90%-sparse model's v2
/// checkpoint is under 25% of the v1 dense size, mid-run (after the
/// touched set has accumulated refresh churn — the bound holds even if
/// consecutive top-k selections were completely disjoint).
#[test]
fn v2_checkpoint_of_90pct_sparse_model_is_under_quarter_of_v1() {
    let synth = Synthetic::small();
    let mut trainer = trainer_at(&synth, 0.9, cfg(8, 4, 7));
    for _ in 0..8 {
        trainer.train_step().unwrap();
    }
    let ck = trainer.capture_checkpoint().unwrap();
    let dense = Checkpoint::capture_dense(&trainer.store, trainer.opt_slots(), ck.step);

    let dir = std::env::temp_dir().join("topkast_sparse_exchange_size");
    std::fs::create_dir_all(&dir).unwrap();
    let v2_path = dir.join("sparse.ckpt");
    let v1_path = dir.join("dense.ckpt");
    ck.save(&v2_path).unwrap();
    dense.save_v1(&v1_path).unwrap();
    let v2_len = std::fs::metadata(&v2_path).unwrap().len();
    let v1_len = std::fs::metadata(&v1_path).unwrap().len();
    assert!(
        4 * v2_len < v1_len,
        "90%-sparse v2 checkpoint is {v2_len} bytes, v1 dense {v1_len} — want < 25%"
    );

    // every sparse tensor actually took the compact representation
    for (name, payload) in &ck.params {
        let sparse_tensor = trainer
            .store
            .get(name)
            .unwrap()
            .masks
            .is_some();
        assert_eq!(
            matches!(payload, TensorPayload::Sparse(_)),
            sparse_tensor,
            "{name}: unexpected payload representation"
        );
    }
}

/// A v2 checkpoint written to disk restores a fresh same-seed trainer
/// to the exact captured state (the disk round-trip counterpart of the
/// in-memory mid-run restore the parity suites pin).
#[test]
fn v2_disk_roundtrip_restores_bit_identical_state() {
    let synth = Synthetic::tiny();
    let mut t1 = trainer_at(&synth, 0.8, cfg(12, 3, 13));
    for _ in 0..7 {
        t1.train_step().unwrap();
    }
    let ck = t1.capture_checkpoint().unwrap();
    let dir = std::env::temp_dir().join("topkast_sparse_exchange_rt");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("mid.ckpt");
    ck.save(&path).unwrap();
    let loaded = Checkpoint::load(&path).unwrap();
    assert_eq!(loaded.step, 7);
    assert_eq!(loaded.params, ck.params);
    assert_eq!(loaded.masks_fwd, ck.masks_fwd);
    assert_eq!(loaded.masks_bwd, ck.masks_bwd);
    assert_eq!(loaded.opt, ck.opt);
    assert_eq!(loaded.touched, ck.touched);

    let mut t2 = trainer_at(&synth, 0.8, cfg(12, 3, 13));
    t2.restore_checkpoint(&loaded).unwrap();
    t2.sync_host().unwrap();
    t1.sync_host().unwrap();
    for (a, b) in t1.store.entries.iter().zip(&t2.store.entries) {
        assert_eq!(a.values, b.values, "θ diverged on {}", a.spec.name);
        match (&a.masks, &b.masks) {
            (Some(ma), Some(mb)) => {
                assert_eq!(ma.fwd(), mb.fwd());
                assert_eq!(ma.bwd(), mb.bwd());
                assert_eq!(ma.touched(), mb.touched());
            }
            (None, None) => {}
            _ => panic!("mask presence mismatch"),
        }
    }
    assert_eq!(t1.opt_slots(), t2.opt_slots());
    // and both runs continue identically
    for s in 7..12 {
        let a = t1.train_step().unwrap();
        let b = t2.train_step().unwrap();
        assert_eq!(a, b, "post-restore loss diverged at step {s}");
    }
}

/// The pinned fixture: a v1 checkpoint written by the legacy dense
/// writer (fixed bytes in-tree) loads into the new store bit-identically
/// — the forever-compatibility contract for old checkpoints.
#[test]
fn pinned_v1_fixture_loads_bit_identically() {
    use topkast::runtime::manifest::{InitKind, ParamSpec};
    use topkast::tensor::Shape;

    let path = concat!(
        env!("CARGO_MANIFEST_DIR"),
        "/rust/tests/fixtures/checkpoint_v1_dense.ckpt"
    );
    let ck = Checkpoint::load(path).unwrap();
    assert_eq!(ck.step, 4242);
    assert_eq!(ck.seed, None, "v1 carries no seed");

    let w = [0.5f32, -1.25, 2.0, -0.125, 3.5, 0.0625, -7.75, 0.25];
    let b = [1.0f32, -2.0, 0.5, 4.0];
    let s0 = [1.5f32, -0.5, 0.75, 0.0, 2.5, -1.0, 0.125, 8.0];
    let s1 = [0.25f32, 0.5, -0.75, 1.0];
    assert_eq!(ck.params.len(), 2);
    assert_eq!(ck.params[0].0, "w");
    assert_eq!(ck.params[0].1, TensorPayload::Dense(w.to_vec()));
    assert_eq!(ck.params[1].1, TensorPayload::Dense(b.to_vec()));
    assert_eq!(ck.masks_fwd[0].1.indices(), &[0, 2, 7]);
    assert_eq!(ck.masks_bwd[0].1.indices(), &[0, 1, 2, 7]);
    assert_eq!(ck.opt.len(), 2);
    assert_eq!(ck.opt[0], TensorPayload::Dense(s0.to_vec()));
    assert_eq!(ck.opt[1], TensorPayload::Dense(s1.to_vec()));

    // restores into a store of ANY seed (dense payloads need no init
    // reconstruction), bit-identically
    let specs = vec![
        ParamSpec {
            name: "w".into(),
            shape: Shape::new(&[8]),
            init: InitKind::Normal,
            init_scale: 0.1,
            sparse: true,
            mac: 8,
        },
        ParamSpec {
            name: "b".into(),
            shape: Shape::new(&[4]),
            init: InitKind::Zeros,
            init_scale: 0.0,
            sparse: false,
            mac: 0,
        },
    ];
    let mut store = ParamStore::init(&specs, 987_654);
    let mut opt = vec![vec![0.0f32; 8], vec![0.0f32; 4]];
    ck.restore(&mut store, &mut opt).unwrap();
    assert_eq!(store.get("w").unwrap().values, w);
    assert_eq!(store.get("b").unwrap().values, b);
    let m = store.get("w").unwrap().masks.as_ref().unwrap();
    assert_eq!(m.fwd().indices(), &[0, 2, 7]);
    assert_eq!(m.bwd().indices(), &[0, 1, 2, 7]);
    assert_eq!(m.touched(), &SparseSet::full(8), "v1 history is unknown → full");
    assert_eq!(opt[0], s0.to_vec());
    assert_eq!(opt[1], s1.to_vec());
}

/// The pinned v2 fixture: a TKC2 compact sparse checkpoint with fixed
/// in-tree bytes (written by `gen_checkpoint_v2_sparse.py`) loads
/// bit-identically — the forever-compatibility contract for the sparse
/// format, mirroring the TKC1 fixture above. The sparse param stores
/// values only at its touched set; everything outside it is
/// reconstructed by replaying the recorded init seed.
#[test]
fn pinned_v2_sparse_fixture_loads_bit_identically() {
    use topkast::runtime::manifest::{InitKind, ParamSpec};
    use topkast::sparsity::replay_init_values;
    use topkast::tensor::Shape;

    let path = concat!(
        env!("CARGO_MANIFEST_DIR"),
        "/rust/tests/fixtures/checkpoint_v2_sparse.ckpt"
    );
    let ck = Checkpoint::load(path).unwrap();
    assert_eq!(ck.step, 4242);
    assert_eq!(ck.seed, Some(31), "v2 records the init seed");

    let touched = [0u32, 1, 2, 3, 7];
    let w_vals = [0.5f32, -1.25, 2.0, -0.125, -7.75];
    let b = [1.0f32, -2.0, 0.5, 4.0];
    let s0 = [0.25f32, 0.125, -0.5, 0.0625, 8.0];
    let s1 = [0.0625f32, 0.0, -1.0, 2.5];
    assert_eq!(ck.params.len(), 2);
    assert_eq!(ck.params[0].0, "w");
    let TensorPayload::Sparse(slice) = &ck.params[0].1 else {
        panic!("w is stored sparsely");
    };
    assert_eq!(slice.indices.indices(), &touched);
    assert_eq!(slice.indices.domain(), 8);
    assert_eq!(slice.values, w_vals);
    assert_eq!(ck.params[1].1, TensorPayload::Dense(b.to_vec()));
    assert_eq!(ck.masks_fwd[0].1.indices(), &[0, 2, 7]);
    assert_eq!(ck.masks_bwd[0].1.indices(), &[0, 1, 2, 7]);
    // the sparse opt slot came back aligned to w's touched set
    assert_eq!(ck.opt.len(), 2);
    let TensorPayload::Sparse(opt0) = &ck.opt[0] else {
        panic!("slot0 is stored sparsely");
    };
    assert_eq!(opt0.indices.indices(), &touched);
    assert_eq!(opt0.values, s0);
    assert_eq!(ck.opt[1], TensorPayload::Dense(s1.to_vec()));

    let specs = vec![
        ParamSpec {
            name: "w".into(),
            shape: Shape::new(&[8]),
            init: InitKind::Normal,
            init_scale: 0.1,
            sparse: true,
            mac: 8,
        },
        ParamSpec {
            name: "b".into(),
            shape: Shape::new(&[4]),
            init: InitKind::Zeros,
            init_scale: 0.0,
            sparse: false,
            mac: 0,
        },
    ];
    // expected dense w: replay the recorded seed's init draw, then
    // scatter the stored touched values on top
    let mut w_expect = replay_init_values(&specs[0], 0, 31);
    for (&i, &v) in touched.iter().zip(&w_vals) {
        w_expect[i as usize] = v;
    }

    // read-side API (what the serving plane consumes)
    assert_eq!(ck.param_values(&specs, "w").unwrap(), w_expect);
    assert_eq!(ck.param_values(&specs, "b").unwrap(), b.to_vec());
    assert_eq!(ck.fwd_mask("w").unwrap().indices(), &[0, 2, 7]);

    // restore path — same reconstruction, plus zero opt outside touched
    let mut store = ParamStore::init(&specs, 987_654);
    let mut opt = vec![vec![1.0f32; 8], vec![1.0f32; 4]];
    ck.restore(&mut store, &mut opt).unwrap();
    assert_eq!(store.get("w").unwrap().values, w_expect);
    assert_eq!(store.get("b").unwrap().values, b);
    let m = store.get("w").unwrap().masks.as_ref().unwrap();
    assert_eq!(m.fwd().indices(), &[0, 2, 7]);
    assert_eq!(m.bwd().indices(), &[0, 1, 2, 7]);
    assert_eq!(m.touched().indices(), &touched, "v2 carries the real history");
    let mut s0_expect = [0.0f32; 8];
    for (&i, &v) in touched.iter().zip(&s0) {
        s0_expect[i as usize] = v;
    }
    assert_eq!(opt[0], s0_expect);
    assert_eq!(opt[1], s1.to_vec());
}

/// Every way of cutting the v2 fixture short (or long) produces the
/// matching distinct load error: below the container header, inside the
/// JSON header, at each section boundary of the blob, and past the
/// declared end.
#[test]
fn v2_fixture_truncated_at_every_boundary_errors_distinctly() {
    let path = concat!(
        env!("CARGO_MANIFEST_DIR"),
        "/rust/tests/fixtures/checkpoint_v2_sparse.ckpt"
    );
    let bytes = std::fs::read(path).unwrap();
    let hlen = u64::from_le_bytes(bytes[4..12].try_into().unwrap()) as usize;
    let blob_start = 12 + hlen;
    let blob_len = bytes.len() - blob_start;
    assert_eq!(blob_len, 120, "pinned blob layout");

    let d = std::env::temp_dir().join("topkast_v2_fixture_cuts");
    std::fs::create_dir_all(&d).unwrap();
    let load_cut = |at: usize| {
        let p = d.join(format!("cut_{at}.ckpt"));
        std::fs::write(&p, &bytes[..at]).unwrap();
        Checkpoint::load(&p).unwrap_err().to_string()
    };

    // below the 12-byte container header
    let err = load_cut(8);
    assert!(err.contains("container header"), "{err}");
    // inside the JSON header
    let err = load_cut(12 + hlen / 2);
    assert!(err.contains("header claims"), "{err}");
    // at the start of each blob section (offsets pinned by the
    // generator: param_idx, param_vals, param, mask_fwd, mask_bwd,
    // opt_vals, opt) and one word into the first section
    for cut in [0usize, 4, 20, 40, 56, 68, 84, 104] {
        let err = load_cut(blob_start + cut);
        assert!(
            err.contains(&format!(
                "header declares a {blob_len}-byte blob, file holds {cut}"
            )),
            "cut at blob+{cut}: {err}"
        );
    }
    // longer than declared: the distinct trailing-bytes error
    let p = d.join("long.ckpt");
    let mut long = bytes.clone();
    long.extend_from_slice(&[0u8; 7]);
    std::fs::write(&p, &long).unwrap();
    let err = Checkpoint::load(&p).unwrap_err().to_string();
    assert!(err.contains("7 trailing bytes"), "{err}");
    assert!(!err.contains("truncated"), "trailing ≠ truncated: {err}");
    // the untouched fixture still loads
    Checkpoint::load(path).unwrap();
}

/// Weight-rewriting refresh installs (SET): the device upload is
/// exactly 4·Δindices (mask deltas) + 8·edit-entries (u32 index +
/// f32 value per rewritten weight) — never the dense 4·n re-upload
/// the legacy path moved.
#[test]
fn set_refresh_uploads_exactly_mask_deltas_plus_value_edits_never_dense() {
    use topkast::runtime::{DeviceState, Runtime};
    use topkast::sparsity::{update_store_masks, SetEvolve};
    use topkast::util::rng::Pcg64;

    let synth = Synthetic::small();
    let rt = Runtime::new().unwrap();
    let mut store = ParamStore::init(&synth.model.params, 21);
    let mut strategy = SetEvolve::new(0.2, 0.3, 0.1);
    let mut rng = Pcg64::new(21, 7);
    // step-0 init: masks appear, but no weight rewrites are recorded
    let init_edits =
        update_store_masks(&mut strategy, &mut store, None, &mut rng, 0, 100)
            .unwrap();
    assert!(init_edits.iter().all(|s| s.is_empty()), "SET init rewrites nothing");

    let slots = synth.model.optimizer.slots();
    let opt: Vec<Vec<f32>> = synth
        .model
        .params
        .iter()
        .flat_map(|p| {
            std::iter::repeat_with(move || vec![0.0f32; p.shape.numel()]).take(slots)
        })
        .collect();
    let mut device =
        DeviceState::from_host(rt.client().clone(), &synth.model, &store, &opt)
            .unwrap();

    let installed: Vec<(SparseSet, SparseSet)> = store
        .entries
        .iter()
        .filter_map(|e| e.masks.as_ref().map(|m| (m.fwd().clone(), m.bwd().clone())))
        .collect();
    // the SET rewrite: drop + grow, with every touched weight recorded
    let edits =
        update_store_masks(&mut strategy, &mut store, None, &mut rng, 50, 100)
            .unwrap();
    let entries: u64 = edits.iter().map(|s| s.len() as u64).sum();
    assert!(entries > 0, "a SET refresh rewrites dropped + grown weights");
    let delta: u64 = store
        .entries
        .iter()
        .filter_map(|e| e.masks.as_ref())
        .zip(&installed)
        .map(|(m, (of, ob))| {
            (of.delta_to(m.fwd()).total() + ob.delta_to(m.bwd()).total()) as u64
        })
        .sum();

    let before = rt.transfer_stats();
    device.upload_mask_deltas(&store).unwrap();
    device.upload_sparse_value_edits(&edits).unwrap();
    let moved = rt.transfer_stats().since(&before);
    assert_eq!(
        moved.h2d_bytes,
        4 * delta + 8 * entries,
        "install moves the index deltas plus the (index, value) edit pairs"
    );
    assert_eq!(moved.d2h_bytes, 0, "a refresh install is upload-only");
    let dense_bytes: u64 = synth
        .model
        .sparse_params()
        .iter()
        .map(|p| 4 * p.shape.numel() as u64)
        .sum();
    assert!(
        moved.h2d_bytes < dense_bytes,
        "{} bytes uploaded — the legacy path moved the dense {dense_bytes}",
        moved.h2d_bytes
    );
}

/// Same exactness for RigL, whose rewrites (zeroed drops, zero-init
/// grows) ride the recorded-edit path with host-synthesised gradient
/// magnitudes standing in for the dense-gradient artifact.
#[test]
fn rigl_refresh_uploads_exactly_mask_deltas_plus_value_edits() {
    use topkast::runtime::{DeviceState, Runtime};
    use topkast::sparsity::{update_store_masks, RigL};
    use topkast::util::rng::Pcg64;

    let synth = Synthetic::small();
    let rt = Runtime::new().unwrap();
    let mut store = ParamStore::init(&synth.model.params, 33);
    let mut strategy = RigL::new(0.2, 0.3, 10);
    let mut rng = Pcg64::new(33, 9);
    update_store_masks(&mut strategy, &mut store, None, &mut rng, 0, 1000).unwrap();

    let slots = synth.model.optimizer.slots();
    let opt: Vec<Vec<f32>> = synth
        .model
        .params
        .iter()
        .flat_map(|p| {
            std::iter::repeat_with(move || vec![0.0f32; p.shape.numel()]).take(slots)
        })
        .collect();
    let mut device =
        DeviceState::from_host(rt.client().clone(), &synth.model, &store, &opt)
            .unwrap();

    let mut grad_norms = std::collections::BTreeMap::new();
    let mut grng = Pcg64::new(5, 5);
    for e in &store.entries {
        if e.spec.sparse {
            let g: Vec<f32> =
                (0..e.values.len()).map(|_| grng.normal_f32(1.0).abs()).collect();
            grad_norms.insert(e.spec.name.clone(), g);
        }
    }
    let installed: Vec<(SparseSet, SparseSet)> = store
        .entries
        .iter()
        .filter_map(|e| e.masks.as_ref().map(|m| (m.fwd().clone(), m.bwd().clone())))
        .collect();
    let edits = update_store_masks(
        &mut strategy,
        &mut store,
        Some(&grad_norms),
        &mut rng,
        10,
        1000,
    )
    .unwrap();
    let entries: u64 = edits.iter().map(|s| s.len() as u64).sum();
    assert!(entries > 0, "a RigL update zeroes drops and grows");
    let delta: u64 = store
        .entries
        .iter()
        .filter_map(|e| e.masks.as_ref())
        .zip(&installed)
        .map(|(m, (of, ob))| {
            (of.delta_to(m.fwd()).total() + ob.delta_to(m.bwd()).total()) as u64
        })
        .sum();

    let before = rt.transfer_stats();
    device.upload_mask_deltas(&store).unwrap();
    device.upload_sparse_value_edits(&edits).unwrap();
    let moved = rt.transfer_stats().since(&before);
    assert_eq!(moved.h2d_bytes, 4 * delta + 8 * entries);
    assert_eq!(moved.d2h_bytes, 0);
}

/// End-to-end through the trainer: a SET refresh step's upload is the
/// mask deltas + the step batch + an 8-byte-per-entry edit payload —
/// the TrafficModel's edit account matches the meter, and the total
/// stays far below a dense re-upload. (The exact entry count is pinned
/// at the device level above: dropped-then-regrown indices dedupe to
/// one edit entry but vanish from the mask delta, so it cannot be
/// re-derived from the installed masks here.)
#[test]
fn set_trainer_refresh_traffic_is_edit_sized_not_dense_sized() {
    use topkast::sparsity::SetEvolve;

    let synth = Synthetic::small();
    let mut strategy = SetEvolve::new(0.2, 0.3, 0.1);
    strategy.update_every = 5;
    let mut trainer = synth.trainer(Box::new(strategy), cfg(10, 5, 9)).unwrap();
    let traffic = trainer.traffic().unwrap();
    for _ in 0..5 {
        trainer.train_step().unwrap(); // step-0 init + 4 steady steps
    }
    let installed = mask_sets(&trainer);
    let before = trainer.runtime.transfer_stats();
    trainer.train_step().unwrap(); // step 5: the SET drop/grow refresh
    let moved = trainer.runtime.transfer_stats().since(&before);
    let delta = delta_indices(&trainer, &installed);
    let base = traffic.refresh_h2d_delta_bytes(delta)
        + traffic.refresh_h2d_fixed_bytes
        + traffic.step_h2d_bytes;
    assert!(
        moved.h2d_bytes > base,
        "a SET refresh must carry value edits on top of the mask deltas"
    );
    let extra = moved.h2d_bytes - base;
    assert_eq!(extra % 8, 0, "edits are (u32 index, f32 value) pairs");
    let entries = extra / 8;
    assert_eq!(
        moved.h2d_bytes,
        base + traffic.refresh_h2d_edit_bytes(entries),
        "the TrafficModel edit account closes the meter exactly"
    );
    let dense_bytes: u64 = synth
        .model
        .sparse_params()
        .iter()
        .map(|p| 4 * p.shape.numel() as u64)
        .sum();
    assert!(
        extra < dense_bytes / 4,
        "edit payload {extra} must stay far below the dense rewrite {dense_bytes}"
    );
}

/// v2 checkpoints of an *untrained* store are near-empty: the touched
/// sets are empty, so sparse tensors serialise to indices-only
/// sections — the degenerate end of the O(nnz) scaling.
#[test]
fn untrained_sparse_tensors_checkpoint_to_almost_nothing() {
    let synth = Synthetic::small();
    let store = ParamStore::init(&synth.model.params, 5);
    let slots = synth.model.optimizer.slots();
    let opt: Vec<Vec<f32>> = synth
        .model
        .params
        .iter()
        .flat_map(|p| {
            std::iter::repeat_with(move || vec![0.0f32; p.shape.numel()]).take(slots)
        })
        .collect();
    let ck = Checkpoint::capture(&store, &opt, 0);
    let sparse_stored: usize = ck
        .params
        .iter()
        .filter_map(|(_, p)| match p {
            TensorPayload::Sparse(s) => Some(s.len()),
            TensorPayload::Dense(_) => None,
        })
        .sum();
    assert_eq!(sparse_stored, 0, "untouched tensors store zero values");
    // …and it restores exactly (same-seed store reconstructs init)
    let mut store2 = ParamStore::init(&synth.model.params, 5);
    let mut opt2 = opt.clone();
    ck.restore(&mut store2, &mut opt2).unwrap();
    for (a, b) in store.entries.iter().zip(&store2.entries) {
        assert_eq!(a.values, b.values);
    }
}
