//! Donation-semantics enforcement suite: the full training protocol
//! run against `StrictBackend`, which turns any use of a buffer after
//! its ownership was transferred (PJRT input donation) into a hard
//! error instead of the host-sim's silent tolerance.
//!
//! What this proves: the device-resident chain — step outputs donated
//! into the next step, refresh scatters consuming the old mask
//! buffers, all-reduce payloads donated into the apply step — performs
//! **zero illegal reuses** across ≥3 refresh cycles, under async
//! refresh, across data-parallel replicas, and through a mid-run
//! checkpoint restore. And since strict wraps the same simulator, the
//! results (and the metered transfer counters) must be *bit-identical*
//! to the raw sim backend.
//!
//! Backends are constructed by name (`AnyBackend::from_name`), never
//! from the environment, so the suite is deterministic regardless of
//! `TOPKAST_BACKEND`. CI additionally runs the parity suites under the
//! env matrix.

use topkast::coordinator::{Trainer, TrainerConfig};
use topkast::runtime::{
    AnyBackend, Backend, BufferOps, ExecInput, Runtime, Synthetic,
};
use topkast::sparsity::TopKast;
use topkast::xla;

fn cfg(steps: usize, refresh_every: usize, seed: u64, replicas: usize) -> TrainerConfig {
    TrainerConfig { steps, refresh_every, seed, replicas, ..TrainerConfig::default() }
}

fn strategy() -> Box<TopKast> {
    Box::new(TopKast::from_sparsities(0.8, 0.5))
}

/// A trainer over the named backend, built without touching the
/// process environment (mirrors `Synthetic::trainer`, minus the env
/// switch).
fn trainer_on(backend: &str, synth: &Synthetic, cfg: TrainerConfig) -> Trainer {
    let replicas = cfg.replicas.max(1);
    let client = AnyBackend::from_name(backend, replicas).unwrap();
    let mut rt = Runtime::from_backend(client);
    assert_eq!(rt.backend_name(), backend);
    let synth = if replicas > 1 && synth.model.replication.is_none() {
        synth.replicated(replicas).unwrap()
    } else {
        synth.clone()
    };
    synth.install(&mut rt).unwrap();
    let data = synth.data(cfg.seed ^ 0xDA7A);
    Trainer::new(rt, synth.model.clone(), strategy(), data, cfg).unwrap()
}

/// `x + x` on one input, compiled for the given backend.
fn double_exe(
    client: &AnyBackend,
    len: usize,
) -> <AnyBackend as Backend>::Executable {
    let b = xla::XlaBuilder::new("double");
    let x = b
        .parameter_s(0, &xla::Shape::array::<f32>(vec![len]), "x")
        .unwrap();
    let comp = b.tuple(&[(&x + &x).unwrap()]).unwrap().build().unwrap();
    client.compile(&comp).unwrap()
}

#[test]
fn use_after_donate_is_rejected_through_every_alias() {
    let client = AnyBackend::strict(1).unwrap();
    let exe = double_exe(&client, 3);
    let buf = client
        .buffer_from_host_buffer::<f32>(&[1.0, 2.0, 3.0], &[3], None)
        .unwrap();
    let alias = buf.clone();

    let outs = client.execute(&exe, vec![ExecInput::Donate(buf)]).unwrap();
    let root = outs.into_iter().next().unwrap();
    let parts = root.tuple_parts().unwrap();
    let got = parts[0].to_literal_sync().unwrap().to_vec::<f32>().unwrap();
    assert_eq!(got, vec![2.0, 4.0, 6.0]);

    // the donation killed the clone too — every data access errors
    let err = alias.to_literal_sync().unwrap_err().to_string();
    assert!(err.contains("use-after-donate"), "{err}");
    let err = alias.gather_to_host(&[0]).unwrap_err().to_string();
    assert!(err.contains("use-after-donate"), "{err}");
    let err = client
        .execute(&exe, vec![ExecInput::Borrow(&alias)])
        .unwrap_err()
        .to_string();
    assert!(err.contains("use-after-donate"), "{err}");
    assert!(alias.debug_read_f32().is_none(), "no free peek at dead memory");
    // host-side metadata stays readable (PJRT keeps it off-device)
    assert_eq!(alias.element_count(), 3);
}

#[test]
fn borrowed_inputs_survive_execution_and_tuples_donate() {
    let client = AnyBackend::strict(1).unwrap();
    let exe = double_exe(&client, 2);
    let buf = client
        .buffer_from_host_buffer::<f32>(&[5.0, 7.0], &[2], None)
        .unwrap();
    // borrow twice: the buffer must remain valid between and after
    for _ in 0..2 {
        let outs = client.execute(&exe, vec![ExecInput::Borrow(&buf)]).unwrap();
        let parts = outs.into_iter().next().unwrap().tuple_parts().unwrap();
        let got = parts[0].to_literal_sync().unwrap().to_vec::<f32>().unwrap();
        assert_eq!(got, vec![10.0, 14.0]);
    }
    assert_eq!(
        buf.to_literal_sync().unwrap().to_vec::<f32>().unwrap(),
        vec![5.0, 7.0]
    );

    // splitting a tuple consumes the tuple handle
    let outs = client.execute(&exe, vec![ExecInput::Borrow(&buf)]).unwrap();
    let root = outs.into_iter().next().unwrap();
    let root_alias = root.clone();
    let _parts = root.tuple_parts().unwrap();
    let err = root_alias.tuple_parts().unwrap_err().to_string();
    assert!(err.contains("use-after-donate"), "{err}");
}

#[test]
fn strict_trainer_runs_refresh_cycles_and_checkpoint_restore_clean() {
    for synth in [Synthetic::tiny(), Synthetic::small()] {
        // 11 steps / refresh every 3 → refreshes at 0, 3, 6, 9 (≥3 full
        // cycles). Any illegal reuse in the chain → hard error → unwrap
        // panics.
        let steps = 11;
        let mut t = trainer_on("strict", &synth, cfg(steps, 3, 5, 1));
        for _ in 0..7 {
            t.train_step().unwrap();
        }
        // eval + grad_norms borrow the resident params mid-chain (the
        // documented escape hatch) — the chain must continue afterwards
        t.evaluate().unwrap();
        let ck = t.capture_checkpoint().unwrap();
        assert_eq!(ck.step, 7);
        for _ in 7..steps {
            t.train_step().unwrap();
        }

        // restore mid-run state into a *fresh* strict trainer and keep
        // going: the wholesale re-upload must rebuild a clean chain
        let mut resumed = trainer_on("strict", &synth, cfg(steps, 3, 5, 1));
        resumed.restore_checkpoint(&ck).unwrap();
        for _ in 7..steps {
            resumed.train_step().unwrap();
        }
        resumed.evaluate().unwrap();
    }
}

#[test]
fn strict_trainer_runs_async_refresh_clean() {
    let synth = Synthetic::tiny();
    let mut t = trainer_on("strict", &synth, cfg(11, 3, 7, 1));
    t.enable_async_refresh(strategy()).unwrap();
    for _ in 0..11 {
        t.train_step().unwrap();
    }
    t.evaluate().unwrap();
}

#[test]
fn strict_trainer_runs_replicated_clean() {
    // 4 replicas: grad payloads all-reduced, reduced buffers donated
    // into each replica's apply step, masks broadcast per device
    let synth = Synthetic::tiny();
    let mut t = trainer_on("strict", &synth, cfg(11, 3, 9, 4));
    assert_eq!(t.replica_count(), 4);
    for _ in 0..11 {
        t.train_step().unwrap();
    }
    t.verify_replica_lockstep().unwrap();
    t.evaluate().unwrap();
}

#[test]
fn sim_and_strict_are_bitwise_identical_including_transfer_counters() {
    for replicas in [1usize, 2] {
        let synth = Synthetic::tiny();
        let steps = 11;
        let mut sim = trainer_on("sim", &synth, cfg(steps, 3, 5, replicas));
        let mut strict = trainer_on("strict", &synth, cfg(steps, 3, 5, replicas));
        for s in 0..steps {
            let a = sim.train_step().unwrap();
            let b = strict.train_step().unwrap();
            assert_eq!(a, b, "x{replicas}: loss diverged at step {s}");
        }
        let ea = sim.evaluate().unwrap();
        let eb = strict.evaluate().unwrap();
        assert_eq!(ea.loss_mean, eb.loss_mean, "x{replicas}: eval loss");

        sim.sync_host().unwrap();
        strict.sync_host().unwrap();
        for (ea, eb) in sim.store.entries.iter().zip(&strict.store.entries) {
            assert_eq!(ea.values, eb.values, "params diverged on {}", ea.spec.name);
            match (&ea.masks, &eb.masks) {
                (Some(ma), Some(mb)) => {
                    assert_eq!(ma.fwd(), mb.fwd(), "fwd mask {}", ea.spec.name);
                    assert_eq!(ma.bwd(), mb.bwd(), "bwd mask {}", ea.spec.name);
                }
                (None, None) => {}
                _ => panic!("mask presence mismatch on {}", ea.spec.name),
            }
        }
        assert_eq!(sim.opt_slots(), strict.opt_slots(), "optimiser state");

        // enforcement is free on the wire: the metered counters the
        // parity suites pin must be identical snapshot-for-snapshot
        assert_eq!(
            sim.runtime.transfer_stats(),
            strict.runtime.transfer_stats(),
            "x{replicas}: transfer counters"
        );
        for r in 0..replicas {
            assert_eq!(
                sim.runtime.device_transfer_stats(r).unwrap(),
                strict.runtime.device_transfer_stats(r).unwrap(),
                "x{replicas}: device {r} counters"
            );
        }
    }
}
