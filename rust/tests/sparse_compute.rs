//! Dense-reference parity harness for the O(nnz) compute kernels.
//!
//! The determinism contract under test (see `xla` module docs): the
//! sparse gather-matmul / lazy-select / masked-scatter kernels produce
//! results **bit-identical** to the dense reference executor, at any
//! thread count, because both sides reduce with the same canonical
//! pairwise tree and the sparse side only replaces subtrees whose
//! terms are all exact +0.0 with the literal +0.0.
//!
//! The host references below are *independent reimplementations* of
//! the documented contract (a recursive `ceil(n/2)`-split pairwise
//! sum over the dense term vector), not calls into the executor — so
//! a regression in either kernel shows up as a bit mismatch here.
//!
//! Run under `TOPKAST_BACKEND={sim,strict}` and
//! `TOPKAST_THREADS={1,4}` in CI; the trainer-level test below also
//! varies kernel mode and thread count explicitly.

use topkast::coordinator::TrainerConfig;
use topkast::runtime::{env_backend_name, AnyBackend, Runtime, StrictBackend, Synthetic};
use topkast::sparsity::TopKast;
use topkast::util::proptest::{ensure, property_cases};
use topkast::util::rng::Pcg64;
use topkast::xla::{KernelMode, PjRtClient, Shape, XlaBuilder};

const THREAD_COUNTS: [usize; 4] = [1, 2, 4, 8];
const SPARSITIES: [f64; 3] = [0.5, 0.8, 0.98];

/// The documented reduction order: recursive pairwise with the split
/// at `ceil(n/2)`. Every per-output-element sum in the executor —
/// dense or sparse, sequential or parallel — must match this tree.
fn ref_pairwise(v: &[f32]) -> f32 {
    match v.len() {
        0 => 0.0,
        1 => v[0],
        n => {
            let half = n.div_ceil(2);
            ref_pairwise(&v[..half]) + ref_pairwise(&v[half..])
        }
    }
}

fn bits(v: &[f32]) -> Vec<u32> {
    v.iter().map(|x| x.to_bits()).collect()
}

/// Sorted mask index sample: `nnz` distinct positions in `[0, numel)`.
fn sample_mask(rng: &mut Pcg64, numel: usize, sparsity: f64) -> Vec<u32> {
    let nnz = ((numel as f64) * (1.0 - sparsity)).round() as usize;
    let mut idx: Vec<u32> = rng
        .sample_indices(numel, nnz.min(numel))
        .into_iter()
        .map(|i| i as u32)
        .collect();
    idx.sort_unstable();
    idx
}

fn dense_mask(numel: usize, idx: &[u32]) -> Vec<f32> {
    let mut m = vec![0.0f32; numel];
    for &i in idx {
        m[i as usize] = 1.0;
    }
    m
}

// ---------------------------------------------------------------------------
// gather-matmul vs dense reference
// ---------------------------------------------------------------------------

/// z = masked_matmul(x, w, mask) and loss = mean(z ⊙ z), against a
/// host reference, bitwise, across kernels × thread counts ×
/// sparsities, with the mask passed both as an index-set sidecar
/// buffer and as a plain dense 0/1 payload (no sidecar — the sparse
/// kernel must fall back to the dense path and still match).
#[test]
fn gather_matmul_matches_dense_reference_bitwise() {
    property_cases("gather_matmul_parity", 24, |rng| {
        let m = 1 + rng.next_below(6) as usize;
        let k = 1 + rng.next_below(12) as usize;
        let n = 1 + rng.next_below(12) as usize;
        let sparsity = SPARSITIES[rng.next_below(3) as usize];
        let idx = sample_mask(rng, k * n, sparsity);
        let xs: Vec<f32> = (0..m * k).map(|_| rng.normal_f32(1.0)).collect();
        let ws: Vec<f32> = (0..k * n).map(|_| rng.normal_f32(1.0)).collect();
        let mask = dense_mask(k * n, &idx);

        // host reference: dense term vector, masked entries exact +0.0
        let mut want_z = vec![0.0f32; m * n];
        for i in 0..m {
            for o in 0..n {
                let terms: Vec<f32> = (0..k)
                    .map(|f| {
                        if mask[f * n + o] != 0.0 {
                            xs[i * k + f] * ws[f * n + o]
                        } else {
                            0.0
                        }
                    })
                    .collect();
                want_z[i * n + o] = ref_pairwise(&terms);
            }
        }
        let z2: Vec<f32> = want_z.iter().map(|z| z * z).collect();
        let want_loss = ref_pairwise(&z2) / (m * n) as f32;
        let want_macs = m as u64 * idx.len() as u64;

        for kernel in [KernelMode::Dense, KernelMode::Sparse] {
            for threads in THREAD_COUNTS {
                for sidecar in [true, false] {
                    let client = PjRtClient::cpu()
                        .map_err(|e| e.to_string())?
                        .with_kernel(kernel)
                        .with_threads(threads);
                    let b = XlaBuilder::new("gmm");
                    let build = || -> anyhow::Result<_> {
                        let x =
                            b.parameter_s(0, &Shape::array::<f32>(vec![m, k]), "x")?;
                        let w =
                            b.parameter_s(1, &Shape::array::<f32>(vec![k, n]), "w")?;
                        let mk = b
                            .parameter_s(2, &Shape::array::<f32>(vec![k * n]), "m")?;
                        let z = b.masked_matmul(&x, &w, &mk, m, k, n)?;
                        let loss = (z.clone() * z.clone())?.mean()?;
                        Ok(b.tuple(&[z, loss])?.build()?)
                    };
                    let comp = build().map_err(|e| e.to_string())?;
                    let exe = client.compile(&comp).map_err(|e| e.to_string())?;
                    let bx = client
                        .buffer_from_host_buffer::<f32>(&xs, &[m, k], None)
                        .map_err(|e| e.to_string())?;
                    let bw = client
                        .buffer_from_host_buffer::<f32>(&ws, &[k, n], None)
                        .map_err(|e| e.to_string())?;
                    let bm = if sidecar {
                        client
                            .mask_from_indices(&[k * n], &idx, None)
                            .map_err(|e| e.to_string())?
                    } else {
                        client
                            .buffer_from_host_buffer::<f32>(&mask, &[k * n], None)
                            .map_err(|e| e.to_string())?
                    };
                    client.reset_kernel_macs();
                    let out =
                        exe.execute_b(&[&bx, &bw, &bm]).map_err(|e| e.to_string())?;
                    let parts =
                        out[0][0].tuple_parts().map_err(|e| e.to_string())?;
                    let got_z = parts[0]
                        .to_literal_sync()
                        .and_then(|l| l.to_vec::<f32>())
                        .map_err(|e| e.to_string())?;
                    let got_loss = parts[1]
                        .to_literal_sync()
                        .and_then(|l| l.to_vec::<f32>())
                        .map_err(|e| e.to_string())?;
                    let tag = format!(
                        "m={m} k={k} n={n} s={sparsity} kernel={kernel:?} \
                         threads={threads} sidecar={sidecar}"
                    );
                    ensure(bits(&got_z) == bits(&want_z), format!("z bits: {tag}"))?;
                    ensure(
                        got_loss.len() == 1
                            && got_loss[0].to_bits() == want_loss.to_bits(),
                        format!("loss bits: {tag}"),
                    )?;
                    ensure(
                        client.kernel_macs() == want_macs,
                        format!(
                            "macs {} != {want_macs}: {tag}",
                            client.kernel_macs()
                        ),
                    )?;
                }
            }
        }
        Ok(())
    });
}

// ---------------------------------------------------------------------------
// select / scatter_add vs dense reference
// ---------------------------------------------------------------------------

/// act = θ.select(mask), total = Σ act (pruned sparse reduction), and
/// stepped = θ.scatter_add(mask, θ·0.5) — with a −0.0 planted in θ to
/// exercise the off-mask hazards: select must emit literal +0.0 off
/// the mask (not θ·0, which would give −0.0), and scatter_add must
/// byte-copy the base off the mask.
#[test]
fn select_and_scatter_add_match_dense_reference_bitwise() {
    property_cases("select_scatter_parity", 24, |rng| {
        let len = 1 + rng.next_below(64) as usize;
        let sparsity = SPARSITIES[rng.next_below(3) as usize];
        let idx = sample_mask(rng, len, sparsity);
        let mask = dense_mask(len, &idx);
        let mut theta: Vec<f32> = (0..len).map(|_| rng.normal_f32(0.5)).collect();
        theta[rng.next_below(len as u64) as usize] = -0.0;

        let want_act: Vec<f32> = (0..len)
            .map(|i| if mask[i] != 0.0 { theta[i] } else { 0.0 })
            .collect();
        let want_total = ref_pairwise(&want_act);
        let want_stepped: Vec<f32> = (0..len)
            .map(|i| {
                if mask[i] != 0.0 {
                    theta[i] + theta[i] * 0.5
                } else {
                    theta[i]
                }
            })
            .collect();

        for kernel in [KernelMode::Dense, KernelMode::Sparse] {
            for threads in THREAD_COUNTS {
                for sidecar in [true, false] {
                    let client = PjRtClient::cpu()
                        .map_err(|e| e.to_string())?
                        .with_kernel(kernel)
                        .with_threads(threads);
                    let b = XlaBuilder::new("sel_scatter");
                    let build = || -> anyhow::Result<_> {
                        let t =
                            b.parameter_s(0, &Shape::array::<f32>(vec![len]), "t")?;
                        let mk =
                            b.parameter_s(1, &Shape::array::<f32>(vec![len]), "m")?;
                        let act = t.select(&mk)?;
                        let total = act.reduce_sum()?;
                        let upd = (&t * b.constant_f32(0.5)?)?;
                        let stepped = t.scatter_add(&mk, &upd)?;
                        Ok(b.tuple(&[act, total, stepped])?.build()?)
                    };
                    let comp = build().map_err(|e| e.to_string())?;
                    let exe = client.compile(&comp).map_err(|e| e.to_string())?;
                    let bt = client
                        .buffer_from_host_buffer::<f32>(&theta, &[len], None)
                        .map_err(|e| e.to_string())?;
                    let bm = if sidecar {
                        client
                            .mask_from_indices(&[len], &idx, None)
                            .map_err(|e| e.to_string())?
                    } else {
                        client
                            .buffer_from_host_buffer::<f32>(&mask, &[len], None)
                            .map_err(|e| e.to_string())?
                    };
                    let out = exe.execute_b(&[&bt, &bm]).map_err(|e| e.to_string())?;
                    let parts =
                        out[0][0].tuple_parts().map_err(|e| e.to_string())?;
                    let vals: Vec<Vec<f32>> = parts
                        .iter()
                        .map(|p| {
                            p.to_literal_sync()
                                .and_then(|l| l.to_vec::<f32>())
                                .map_err(|e| e.to_string())
                        })
                        .collect::<Result<_, _>>()?;
                    let tag = format!(
                        "len={len} s={sparsity} kernel={kernel:?} \
                         threads={threads} sidecar={sidecar}"
                    );
                    ensure(bits(&vals[0]) == bits(&want_act), format!("act: {tag}"))?;
                    ensure(
                        vals[1].len() == 1
                            && vals[1][0].to_bits() == want_total.to_bits(),
                        format!("total: {tag}"),
                    )?;
                    ensure(
                        bits(&vals[2]) == bits(&want_stepped),
                        format!("stepped: {tag}"),
                    )?;
                }
            }
        }
        Ok(())
    });
}

// ---------------------------------------------------------------------------
// end-to-end training parity over refresh cycles
// ---------------------------------------------------------------------------

/// A client + backend honoring `TOPKAST_BACKEND` (sim or strict) with
/// an explicit kernel mode and thread count — the env var picks the
/// runtime layer, the arguments pick the executor configuration.
fn backend_with(kernel: KernelMode, threads: usize) -> AnyBackend {
    let client = PjRtClient::cpu_with_devices(1)
        .unwrap()
        .with_kernel(kernel)
        .with_threads(threads);
    match env_backend_name() {
        "strict" | "faulty-strict" => {
            AnyBackend::Strict(StrictBackend::from_client(client))
        }
        _ => AnyBackend::Sim(client),
    }
}

/// Everything a training run produces, bit-exact.
#[derive(PartialEq, Eq, Debug)]
struct RunPrint {
    losses: Vec<u64>,
    eval_loss: u64,
    params: Vec<Vec<u32>>,
    masks: Vec<(Vec<u32>, Vec<u32>)>,
    slots: Vec<Vec<u32>>,
}

fn run_training(kernel: KernelMode, threads: usize) -> RunPrint {
    let synth = Synthetic::tiny();
    let rt = Runtime::from_backend(backend_with(kernel, threads));
    let cfg = TrainerConfig {
        steps: 10,
        refresh_every: 3, // refreshes at steps 0, 3, 6, 9 — four cycles
        seed: 17,
        ..TrainerConfig::default()
    };
    let mut trainer = synth
        .trainer_on(rt, Box::new(TopKast::from_sparsities(0.8, 0.5)), cfg)
        .unwrap();
    let losses: Vec<u64> = (0..10)
        .map(|_| trainer.train_step().unwrap().to_bits())
        .collect();
    let eval_loss = trainer.evaluate().unwrap().loss_mean.to_bits();
    trainer.sync_host().unwrap();
    let params = trainer
        .store
        .entries
        .iter()
        .map(|e| bits(&e.values))
        .collect();
    let masks = trainer
        .store
        .entries
        .iter()
        .filter_map(|e| e.masks.as_ref())
        .map(|m| (m.fwd().indices().to_vec(), m.bwd().indices().to_vec()))
        .collect();
    let slots = trainer.opt_slots().iter().map(|s| bits(s)).collect();
    RunPrint { losses, eval_loss, params, masks, slots }
}

/// The full training loop — losses, params, masks, optimizer slots,
/// eval — is bit-identical dense-vs-sparse and at every thread count,
/// across ≥3 mask refresh cycles (so refresh value-edit uploads, mask
/// delta installs, and the O(nnz) kernels all sit on the path).
#[test]
fn training_is_bit_identical_dense_vs_sparse_over_refresh_cycles() {
    let baseline = run_training(KernelMode::Dense, 1);
    assert_eq!(baseline.losses.len(), 10);
    assert!(!baseline.masks.is_empty(), "tiny model has sparse tensors");
    for kernel in [KernelMode::Dense, KernelMode::Sparse] {
        for threads in THREAD_COUNTS {
            if kernel == KernelMode::Dense && threads == 1 {
                continue;
            }
            let got = run_training(kernel, threads);
            assert_eq!(
                got, baseline,
                "kernel={kernel:?} threads={threads} diverged from dense/1 \
                 under backend={}",
                env_backend_name()
            );
        }
    }
}
