//! Integration: the rust runtime against the real AOT artifacts.
//! Requires `make artifacts`; every test skips (with a note) when the
//! artifacts are not built, so artifact-less CI stays green.

use topkast::runtime::{Manifest, Optimizer, Runtime};
use topkast::sparsity::ParamStore;
use topkast::tensor::{HostTensor, Shape, TensorData};

/// The manifest, or an early `return` that skips the calling test
/// when artifacts are not built.
macro_rules! require_artifacts {
    () => {
        match Manifest::load(concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts")) {
            Ok(man) => man,
            Err(_) => {
                eprintln!("skipping: artifacts not built (run `make artifacts`)");
                return;
            }
        }
    };
}

/// Clone a store's params/masks into owned HostTensors (test-local:
/// the library itself marshals borrowed slices / resident buffers and
/// no longer exposes clone-returning helpers).
fn param_tensors(store: &ParamStore) -> Vec<HostTensor> {
    store
        .entries
        .iter()
        .map(|e| HostTensor {
            shape: Shape(e.spec.shape.dims().to_vec()),
            data: TensorData::F32(e.values.clone()),
        })
        .collect()
}

fn mask_tensors(store: &ParamStore, fwd: bool) -> Vec<HostTensor> {
    store
        .entries
        .iter()
        .filter_map(|e| {
            e.masks.as_ref().map(|m| HostTensor {
                shape: Shape(e.spec.shape.dims().to_vec()),
                data: TensorData::F32(if fwd { m.fwd_dense() } else { m.bwd_dense() }),
            })
        })
        .collect()
}

/// Build a full train-step input vector for a model with given masks.
fn train_inputs(
    man: &Manifest,
    name: &str,
    d_fwd: f64,
    d_bwd: f64,
    seed: u64,
) -> (Vec<HostTensor>, ParamStore) {
    let model = man.model(name).unwrap();
    let mut store = ParamStore::init(&model.params, seed);
    // top-k masks straight from the sparsity module
    for e in store.entries.iter_mut() {
        if let Some(m) = e.masks.as_mut() {
            let n = e.values.len();
            let ka = topkast::sparsity::topk::k_for_density(n, d_fwd);
            let kb = topkast::sparsity::topk::k_for_density(n, d_bwd).max(ka);
            m.set_fwd(topkast::sparsity::topk::topk_mask(&e.values, ka));
            m.set_bwd(topkast::sparsity::topk::topk_mask(&e.values, kb));
        }
    }
    let mut inputs = param_tensors(&store);
    inputs.extend(mask_tensors(&store, true));
    inputs.extend(mask_tensors(&store, false));
    let slots = model.optimizer.slots();
    for p in &model.params {
        for _ in 0..slots {
            inputs.push(HostTensor {
                shape: Shape(p.shape.dims().to_vec()),
                data: TensorData::F32(vec![0.0; p.shape.numel()]),
            });
        }
    }
    // batch: shapes from the artifact signature
    let spec = &model.train;
    let nb = inputs.len();
    for io in &spec.inputs[nb..nb + 2] {
        let numel = io.shape.numel();
        inputs.push(match io.dtype {
            topkast::runtime::Dtype::F32 => HostTensor {
                shape: io.shape.clone(),
                data: TensorData::F32(
                    (0..numel).map(|i| ((i % 13) as f32) * 0.05).collect(),
                ),
            },
            topkast::runtime::Dtype::I32 => HostTensor {
                shape: io.shape.clone(),
                data: TensorData::I32(
                    (0..numel).map(|i| (i % 10) as i32).collect(),
                ),
            },
        });
    }
    for v in [0.05f32, 1.0, 1e-4, (1.0 / d_fwd) as f32] {
        inputs.push(HostTensor::scalar_f32(v));
    }
    (inputs, store)
}

#[test]
fn all_artifacts_compile() {
    let man = require_artifacts!();
    let mut rt = Runtime::new().unwrap();
    for (name, model) in &man.models {
        for spec in [&model.train, &model.eval, &model.grad_norms] {
            let exe = rt.load(spec).unwrap();
            assert!(
                exe.compile_ms >= 0.0,
                "{name}: {:?} failed to compile",
                spec.file
            );
        }
    }
}

#[test]
fn train_step_executes_and_respects_backward_mask() {
    let man = require_artifacts!();
    let mut rt = Runtime::new().unwrap();
    let model = man.model("mlp_tiny").unwrap();
    let (inputs, store) = train_inputs(&man, "mlp_tiny", 0.2, 0.5, 3);
    let exe = rt.load(&model.train).unwrap();
    let outs = exe.run(&inputs).unwrap();

    let np = model.params.len();
    let slots = model.optimizer.slots();
    assert_eq!(outs.len(), np * (1 + slots) + 1);

    // loss is a finite positive number (cross-entropy of ~10 classes)
    let loss = outs.last().unwrap().as_f32().unwrap()[0];
    assert!(loss.is_finite() && loss > 0.0, "loss {loss}");

    // §2.2: coordinates outside B must be bit-identical after the update
    for (i, p) in model.params.iter().enumerate() {
        if !p.sparse {
            continue;
        }
        let before = &store.get(&p.name).unwrap().values;
        let masks = store.get(&p.name).unwrap().masks.as_ref().unwrap();
        let after = outs[i].as_f32().unwrap();
        let mut changed_outside = 0;
        let mut changed_inside = 0;
        for j in 0..before.len() {
            if (before[j] - after[j]).abs() > 0.0 {
                if masks.bwd().contains(j as u32) {
                    changed_inside += 1;
                } else {
                    changed_outside += 1;
                }
            }
        }
        assert_eq!(changed_outside, 0, "{}: updates leaked outside B", p.name);
        assert!(changed_inside > 0, "{}: no updates inside B at all", p.name);
    }
}

#[test]
fn forward_ignores_masked_weights_end_to_end() {
    // Perturb weights outside the forward mask; eval loss must not move.
    let man = require_artifacts!();
    let mut rt = Runtime::new().unwrap();
    let model = man.model("mlp_tiny").unwrap();
    let (_, store) = train_inputs(&man, "mlp_tiny", 0.2, 0.5, 5);

    let build_eval_inputs = |store: &ParamStore| {
        let mut v = param_tensors(store);
        v.extend(mask_tensors(store, true));
        let nb = v.len();
        for io in &model.eval.inputs[nb..nb + 2] {
            let numel = io.shape.numel();
            v.push(match io.dtype {
                topkast::runtime::Dtype::F32 => HostTensor {
                    shape: io.shape.clone(),
                    data: TensorData::F32(
                        (0..numel).map(|i| ((i % 7) as f32) * 0.1).collect(),
                    ),
                },
                topkast::runtime::Dtype::I32 => HostTensor {
                    shape: io.shape.clone(),
                    data: TensorData::I32((0..numel).map(|i| (i % 10) as i32).collect()),
                },
            });
        }
        v
    };

    let exe = rt.load(&model.eval).unwrap();
    let base = exe.run(&build_eval_inputs(&store)).unwrap()[0].as_f32().unwrap()[0];

    let mut store2 = store.clone();
    for e in store2.entries.iter_mut() {
        if let Some(m) = &e.masks {
            for (j, v) in e.values.iter_mut().enumerate() {
                if !m.fwd().contains(j as u32) {
                    *v += 123.0; // huge perturbation outside A
                }
            }
        }
    }
    let perturbed =
        exe.run(&build_eval_inputs(&store2)).unwrap()[0].as_f32().unwrap()[0];
    assert!(
        (base - perturbed).abs() < 1e-4,
        "masked weights leaked into the forward pass: {base} vs {perturbed}"
    );
}

#[test]
fn grad_norms_artifact_gives_dense_signal() {
    let man = require_artifacts!();
    let mut rt = Runtime::new().unwrap();
    let model = man.model("mlp_tiny").unwrap();
    let (_, store) = train_inputs(&man, "mlp_tiny", 0.2, 0.5, 7);

    let mut inputs = param_tensors(&store);
    inputs.extend(mask_tensors(&store, true));
    let nb = inputs.len();
    for io in &model.grad_norms.inputs[nb..nb + 2] {
        let numel = io.shape.numel();
        inputs.push(match io.dtype {
            topkast::runtime::Dtype::F32 => HostTensor {
                shape: io.shape.clone(),
                data: TensorData::F32((0..numel).map(|i| (i % 5) as f32 * 0.2).collect()),
            },
            topkast::runtime::Dtype::I32 => HostTensor {
                shape: io.shape.clone(),
                data: TensorData::I32((0..numel).map(|i| (i % 10) as i32).collect()),
            },
        });
    }
    let exe = rt.load(&model.grad_norms).unwrap();
    let outs = exe.run(&inputs).unwrap();
    assert_eq!(outs.len(), model.sparse_params().len());
    for (out, p) in outs.iter().zip(model.sparse_params()) {
        let g = out.as_f32().unwrap();
        assert!(g.iter().all(|&v| v >= 0.0), "{}: |grad| negative", p.name);
        // the dense gradient must put mass outside the forward mask —
        // that is the whole point of the RigL grow criterion
        let masks = store.get(&p.name).unwrap().masks.as_ref().unwrap();
        let off_mass: f32 = g
            .iter()
            .enumerate()
            .filter(|(j, _)| !masks.fwd().contains(*j as u32))
            .map(|(_, &v)| v)
            .sum();
        assert!(off_mass > 0.0, "{}: no gradient signal outside A", p.name);
    }
}

#[test]
fn adam_and_sgd_artifacts_have_expected_slot_counts() {
    let man = require_artifacts!();
    let lm = man.model("lm_tiny").unwrap();
    assert_eq!(lm.optimizer, Optimizer::Adam);
    assert_eq!(lm.optimizer.slots(), 2);
    let mlp = man.model("mlp_tiny").unwrap();
    assert_eq!(mlp.optimizer, Optimizer::Sgd);
    assert_eq!(mlp.optimizer.slots(), 1);
    // IO arity encodes the slot counts
    let np = lm.params.len();
    let ns = lm.sparse_params().len();
    assert_eq!(lm.train.inputs.len(), np + 2 * ns + 2 * np + 2 + 4);
    let np = mlp.params.len();
    let ns = mlp.sparse_params().len();
    assert_eq!(mlp.train.inputs.len(), np + 2 * ns + np + 2 + 4);
}

#[test]
fn deterministic_execution() {
    // Same inputs → bit-identical outputs (PJRT CPU is deterministic);
    // the experiment tables depend on this.
    let man = require_artifacts!();
    let mut rt = Runtime::new().unwrap();
    let model = man.model("mlp_tiny").unwrap();
    let (inputs, _) = train_inputs(&man, "mlp_tiny", 0.2, 0.5, 11);
    let exe = rt.load(&model.train).unwrap();
    let a = exe.run(&inputs).unwrap();
    let b = exe.run(&inputs).unwrap();
    for (x, y) in a.iter().zip(&b) {
        assert_eq!(x.as_f32().unwrap(), y.as_f32().unwrap());
    }
}
