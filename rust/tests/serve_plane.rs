//! Serving-plane acceptance suite.
//!
//! Proves the three load-bearing properties of `topkast::serve`:
//!
//! 1. **Inference parity** — logits served from a checkpoint are
//!    bitwise identical to the `Trainer`'s own eval on the same state,
//!    for every request, across 1/2/4 simulated devices.
//! 2. **O(Δnnz) hot swap** — a same-run successor swap uploads exactly
//!    `4·Δindices + 4·|changed θ|` bytes per device (recomputed here
//!    from the two checkpoints independently of the swap code), and the
//!    post-swap logits are bitwise identical to a cold load of the
//!    successor. A foreign checkpoint falls back to a full reload.
//! 3. **Strict cleanliness** — the serve path never donates a resident
//!    buffer: the whole suite runs on `StrictBackend` as well as the
//!    host-sim, and repeated inference moves exactly "batch up, logits
//!    down" on the metered counters per execution.
//!
//! Backends are constructed by name (`AnyBackend::from_name`), so the
//! suite is deterministic regardless of `TOPKAST_BACKEND`; CI runs it
//! under the env matrix anyway.

use topkast::coordinator::{Checkpoint, Trainer, TrainerConfig};
use topkast::runtime::{AnyBackend, Runtime, Synthetic};
use topkast::serve::{CheckpointSwapper, Completion, ModelServer, ServeConfig, SwapMode};
use topkast::sparsity::TopKast;
use topkast::tensor::SparseSet;

const BACKENDS: [&str; 2] = ["sim", "strict"];

fn cfg(steps: usize, seed: u64) -> TrainerConfig {
    TrainerConfig { steps, refresh_every: 3, seed, ..TrainerConfig::default() }
}

fn strategy() -> Box<TopKast> {
    Box::new(TopKast::from_sparsities(0.8, 0.5))
}

fn trainer_on(backend: &str, synth: &Synthetic, cfg: TrainerConfig) -> Trainer {
    let client = AnyBackend::from_name(backend, 1).unwrap();
    let mut rt = Runtime::from_backend(client);
    synth.install(&mut rt).unwrap();
    let data = synth.data(cfg.seed ^ 0xDA7A);
    Trainer::new(rt, synth.model.clone(), strategy(), data, cfg).unwrap()
}

fn server_on(
    backend: &str,
    synth: &Synthetic,
    ck: &Checkpoint,
    devices: usize,
    cfg: ServeConfig,
) -> ModelServer {
    let client = AnyBackend::from_name(backend, devices).unwrap();
    let mut rt = Runtime::from_backend(client);
    synth.install(&mut rt).unwrap();
    ModelServer::from_checkpoint(rt, synth.model.clone(), ck, cfg).unwrap()
}

/// The deterministic eval stream as flat request rows: one `(x_row, y)`
/// per example, in eval-batch order.
fn eval_requests(synth: &Synthetic, seed: u64) -> Vec<(Vec<f32>, f32)> {
    let mut data = synth.data(seed ^ 0xDA7A);
    let batch = synth.model.batch_size();
    let mut rows = Vec::new();
    let mut idx = 0;
    while let Some((x, y)) = data.eval_batch(idx) {
        let xs = x.as_f32().unwrap();
        let ys = y.as_f32().unwrap();
        let row_len = xs.len() / batch;
        for slot in 0..batch {
            rows.push((
                xs[slot * row_len..(slot + 1) * row_len].to_vec(),
                ys[slot],
            ));
        }
        idx += 1;
    }
    rows
}

/// Submit the whole eval stream and drain, returning completions.
fn serve_eval_stream(
    server: &mut ModelServer,
    rows: &[(Vec<f32>, f32)],
) -> Vec<Completion> {
    for (x, y) in rows {
        server.submit(x.clone(), *y).unwrap();
    }
    server.drain().unwrap()
}

#[test]
fn served_logits_match_trainer_eval_bitwise_across_device_counts() {
    for backend in BACKENDS {
        let synth = Synthetic::tiny();
        let seed = 5;
        let mut trainer = trainer_on(backend, &synth, cfg(10, seed));
        for _ in 0..10 {
            trainer.train_step().unwrap();
        }
        let ck = trainer.capture_checkpoint().unwrap();

        // the reference: the trainer's own eval on its resident state
        // (which the checkpoint just captured), batch by batch
        let mut reference = Vec::new();
        let mut idx = 0;
        while let Some(out) = trainer.eval_batch_outputs(idx).unwrap() {
            reference.push(out);
            idx += 1;
        }
        assert!(reference.len() >= 2, "need multiple eval batches");

        let rows = eval_requests(&synth, seed);
        let batch = synth.model.batch_size();
        assert_eq!(rows.len(), reference.len() * batch);

        for devices in [1usize, 2, 4] {
            let mut server =
                server_on(backend, &synth, &ck, devices, ServeConfig::default());
            let completions = serve_eval_stream(&mut server, &rows);
            assert_eq!(
                completions.len(),
                reference.len(),
                "{backend} x{devices}: one execution per eval batch"
            );
            for c in &completions {
                // FIFO admission in batch-size chunks keeps request ids
                // aligned with eval batches regardless of placement
                let b = (c.request_ids[0] / batch as u64) as usize;
                let want: Vec<u64> = (0..batch as u64)
                    .map(|i| (b * batch) as u64 + i)
                    .collect();
                assert_eq!(c.request_ids, want, "{backend} x{devices}: batch {b}");
                assert_eq!(c.padded, 0);
                let (loss, metric) = reference[b];
                assert_eq!(
                    c.loss.to_bits(),
                    loss.to_bits(),
                    "{backend} x{devices}: loss of batch {b} (device {})",
                    c.device
                );
                assert_eq!(
                    c.metric.to_bits(),
                    metric.to_bits(),
                    "{backend} x{devices}: metric of batch {b}"
                );
            }
            // everything submitted retired exactly once
            let s = server.stats();
            assert_eq!(s.submitted, rows.len() as u64);
            assert_eq!(s.completed, rows.len() as u64);
            assert_eq!(s.executions, reference.len() as u64);
            assert_eq!(s.padded_rows, 0);
            if devices >= reference.len() {
                // enough devices: every batch launches on its own
                // device on the first tick (least-loaded placement)
                let busy =
                    s.per_device_executions.iter().filter(|&&n| n > 0).count();
                assert_eq!(busy, reference.len(), "{backend} x{devices}: spread");
            }
        }
    }
}

/// Host-side recomputation of what a delta swap must move, straight
/// from the two checkpoints: fwd-mask delta words and changed-θ words.
fn expected_delta(
    synth: &Synthetic,
    a: &Checkpoint,
    b: &Checkpoint,
) -> (usize, usize) {
    let specs = &synth.model.params;
    let mut mask_words = 0usize;
    let mut changed = 0usize;
    for p in specs {
        if p.sparse {
            let fa: &SparseSet = a.fwd_mask(&p.name).unwrap();
            let fb: &SparseSet = b.fwd_mask(&p.name).unwrap();
            mask_words += fa.delta_to(fb).total();
        }
        let va = a.param_values(specs, &p.name).unwrap();
        let vb = b.param_values(specs, &p.name).unwrap();
        changed += va
            .iter()
            .zip(&vb)
            .filter(|(x, y)| x.to_bits() != y.to_bits())
            .count();
    }
    (mask_words, changed)
}

#[test]
fn same_run_swap_moves_exactly_delta_bytes_and_matches_cold_load() {
    for backend in BACKENDS {
        let synth = Synthetic::tiny();
        let seed = 7;
        let mut trainer = trainer_on(backend, &synth, cfg(24, seed));
        for _ in 0..12 {
            trainer.train_step().unwrap();
        }
        let ck_a = trainer.capture_checkpoint().unwrap();
        for _ in 12..24 {
            trainer.train_step().unwrap();
        }
        let ck_b = trainer.capture_checkpoint().unwrap();
        assert_eq!(ck_a.seed, ck_b.seed, "same run records one init seed");

        let (mask_words, changed) = expected_delta(&synth, &ck_a, &ck_b);
        assert!(mask_words > 0, "refresh between captures must move masks");
        assert!(changed > 0, "training between captures must change θ");

        let rows = eval_requests(&synth, seed);
        for devices in [1usize, 2] {
            let mut server =
                server_on(backend, &synth, &ck_a, devices, ServeConfig::default());
            // traffic before the swap, so it is genuinely mid-life
            serve_eval_stream(&mut server, &rows);

            let before = server.transfer_stats();
            let report =
                CheckpointSwapper::new().swap(&mut server, &ck_b).unwrap();
            let moved = server.transfer_stats().since(&before);

            assert_eq!(report.mode, SwapMode::Delta, "{backend} x{devices}");
            assert_eq!(report.delta_index_words, mask_words + changed);
            assert_eq!(report.changed_value_words, changed);
            // the acceptance equation: 4·Δindices + 4·|changed θ| per
            // device, nothing else on the bus
            let expected =
                (devices * (4 * (mask_words + changed) + 4 * changed)) as u64;
            assert_eq!(report.swap_h2d_bytes, expected, "{backend} x{devices}");
            assert_eq!(moved.h2d_bytes, expected, "{backend} x{devices}: metered");
            assert_eq!(moved.d2h_bytes, 0, "a swap downloads nothing");
            assert!(report.swap_h2d_bytes < report.full_upload_bytes);
            assert_eq!(server.installed_step(), ck_b.step);

            // post-swap logits ≡ a cold server loaded from ck_b
            let swapped = serve_eval_stream(&mut server, &rows);
            let mut cold =
                server_on(backend, &synth, &ck_b, devices, ServeConfig::default());
            let cold_outs = serve_eval_stream(&mut cold, &rows);
            assert_eq!(swapped.len(), cold_outs.len());
            for (s, c) in swapped.iter().zip(&cold_outs) {
                assert_eq!(s.request_ids.len(), c.request_ids.len());
                assert_eq!(
                    s.loss.to_bits(),
                    c.loss.to_bits(),
                    "{backend} x{devices}: post-swap loss"
                );
                assert_eq!(s.metric.to_bits(), c.metric.to_bits());
            }
        }
    }
}

#[test]
fn foreign_checkpoint_falls_back_to_full_reload() {
    for backend in BACKENDS {
        let synth = Synthetic::tiny();
        let mut t1 = trainer_on(backend, &synth, cfg(6, 5));
        for _ in 0..6 {
            t1.train_step().unwrap();
        }
        let installed = t1.capture_checkpoint().unwrap();
        // a different seed is a different run — not delta-eligible
        let mut t2 = trainer_on(backend, &synth, cfg(6, 6));
        for _ in 0..6 {
            t2.train_step().unwrap();
        }
        let foreign = t2.capture_checkpoint().unwrap();
        assert_ne!(installed.seed, foreign.seed);

        let rows = eval_requests(&synth, 5);
        let mut server =
            server_on(backend, &synth, &installed, 2, ServeConfig::default());
        serve_eval_stream(&mut server, &rows);

        let before = server.transfer_stats();
        let report = CheckpointSwapper::new().swap(&mut server, &foreign).unwrap();
        let moved = server.transfer_stats().since(&before);
        assert_eq!(report.mode, SwapMode::FullReload, "{backend}");
        // a full reload pays exactly the cold-install cost (dense θ +
        // fwd index uploads, every device)
        assert_eq!(report.swap_h2d_bytes, report.full_upload_bytes, "{backend}");
        assert_eq!(moved.h2d_bytes, report.full_upload_bytes);
        assert_eq!(report.delta_index_words, 0);

        // and the flipped shadows serve the foreign model bit-exactly
        let swapped = serve_eval_stream(&mut server, &rows);
        let mut cold =
            server_on(backend, &synth, &foreign, 2, ServeConfig::default());
        let cold_outs = serve_eval_stream(&mut cold, &rows);
        for (s, c) in swapped.iter().zip(&cold_outs) {
            assert_eq!(s.loss.to_bits(), c.loss.to_bits(), "{backend}");
            assert_eq!(s.metric.to_bits(), c.metric.to_bits(), "{backend}");
        }
    }
}

#[test]
fn strict_serve_streams_exactly_batch_up_logits_down_per_execution() {
    // satellite guarantee: the serve path borrows the resident buffers
    // — repeated inference neither donates them nor moves a byte beyond
    // the request batch (up) and the two scalar logits (down)
    let synth = Synthetic::tiny();
    let seed = 9;
    let mut trainer = trainer_on("strict", &synth, cfg(8, seed));
    for _ in 0..8 {
        trainer.train_step().unwrap();
    }
    let ck = trainer.capture_checkpoint().unwrap();

    let mut server = server_on("strict", &synth, &ck, 1, ServeConfig::default());
    let batch = server.batch_size();
    let row_len = server.row_len();
    let rows = eval_requests(&synth, seed);
    assert!(rows.len() >= batch);

    for round in 0..5 {
        let before = server.transfer_stats();
        for (x, y) in rows.iter().take(batch) {
            server.submit(x.clone(), *y).unwrap();
        }
        let done = server.drain().unwrap();
        assert_eq!(done.len(), 1, "round {round}: one full-batch execution");
        let moved = server.transfer_stats().since(&before);
        // up: x (batch·row_len) + y (batch) f32 words; down: loss+metric
        assert_eq!(
            moved.h2d_bytes,
            (4 * batch * (row_len + 1)) as u64,
            "round {round}: batch up"
        );
        assert_eq!(moved.d2h_bytes, 8, "round {round}: logits down");
    }

    // after arbitrary traffic the resident buffers are still alive and
    // swappable — any donation along the way would have errored above
    // same seed → deterministic replay of the first 8 steps, then 3
    // more: a true same-run successor of the installed checkpoint
    let mut t2 = trainer_on("strict", &synth, cfg(11, seed));
    for _ in 0..11 {
        t2.train_step().unwrap();
    }
    let successor = t2.capture_checkpoint().unwrap();
    let report = CheckpointSwapper::new().swap(&mut server, &successor).unwrap();
    assert_eq!(report.mode, SwapMode::Delta);
    serve_eval_stream(&mut server, &rows);
}

#[test]
fn partial_batches_pad_with_zero_rows_and_account_for_them() {
    let synth = Synthetic::tiny();
    let mut trainer = trainer_on("sim", &synth, cfg(6, 3));
    for _ in 0..6 {
        trainer.train_step().unwrap();
    }
    let ck = trainer.capture_checkpoint().unwrap();
    let mut server = server_on("sim", &synth, &ck, 1, ServeConfig::default());
    let batch = server.batch_size();
    let rows = eval_requests(&synth, 3);

    // one short of a full batch: tick() must hold it, drain() must pad
    for (x, y) in rows.iter().take(batch - 1) {
        server.submit(x.clone(), *y).unwrap();
    }
    assert!(server.tick().unwrap().is_empty(), "partial batch not admitted");
    let done = server.drain().unwrap();
    assert_eq!(done.len(), 1);
    assert_eq!(done[0].padded, 1);
    assert_eq!(server.stats().padded_rows, 1);
    assert_eq!(done[0].request_ids.len(), batch - 1);
}
